"""Quickstart: build a dynamic hypergraph on ESCHER, churn it, count triads.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import update as U
from repro.core.store import EMPTY
from repro.hypergraph import generators as GEN


def main():
    # 1. build: 500 hyperedges over 500 vertices, coauthor-like cardinalities
    edges = GEN.random_hypergraph(500, 500, profile="coauth", max_card=6,
                                  seed=0, skew=0.3)
    hg = H.from_lists(edges, num_vertices=500, max_edges=2048, max_card=8)
    print(f"built hypergraph: {len(edges)} hyperedges, "
          f"free_ptr={int(hg.h2v.free_ptr)} slots used")

    # 2. initial triad census (MoCHy's 26 classes)
    counts = BL.mochy_static(hg, max_deg=32, max_region=2047, chunk=1024)
    print(f"initial triads: total={int(counts.sum())}, "
          f"top classes={np.argsort(-np.asarray(counts))[:4].tolist()}")

    # 3. churn: delete 20 random edges, insert 20 fresh ones, update counts
    #    incrementally (paper Alg. 3) — no recount
    present = np.asarray(hg.h2v.mgr.present)
    live = np.asarray(hg.h2v.mgr.hid)[present == 1]
    rng = np.random.default_rng(1)
    dels = rng.choice(live, 20, replace=False).astype(np.int32)
    ins = GEN.random_hypergraph(20, 500, profile="coauth", max_card=6,
                                seed=2, skew=0.3)
    nl, nc = GEN.pack_lists(ins, 8)
    hg, counts, _ = U.update_triad_counts(
        hg, counts, jnp.asarray(dels), jnp.ones(20, bool),
        jnp.asarray(nl), jnp.asarray(nc), jnp.ones(20, bool),
        max_deg=32, max_region=1023, chunk=1024)
    print(f"after churn (20 del + 20 ins): total={int(counts.sum())}")

    # 4. verify against a full recount — exactness is the paper's claim
    ref = BL.mochy_static(hg, max_deg=32, max_region=2047, chunk=1024)
    assert (np.asarray(counts) == np.asarray(ref)).all()
    print("incremental update == full recount ✓")


if __name__ == "__main__":
    main()
