"""The paper's end-to-end workflow: maintain hyperedge-based, temporal and
incident-vertex triad counts through a stream of churn batches, timing the
incremental update against static recomputation.

    PYTHONPATH=src python examples/dynamic_triads.py [--edges 2000] [--batches 5]

The distributed engine itself lives in ``repro/distributed/triads.py``
(DESIGN.md §3.2): every count here accepts a ``mesh`` and runs sharded on
real devices — ``tests/test_distributed_triads.py`` exercises that on a
host CPU mesh, and ``benchmarks/figures.py::fig18_sharded_scaling``
measures it.  ``--dryrun`` is a thin wrapper over the engine's shared
lowering (``distributed.triads.lower_count_step``): it compiles the sharded
static-count step for the production meshes (single-pod 16×16, multi-pod
2×16×16) without allocating a store and asserts the psum merge survives
into the compiled HLO.
"""
import os
import sys

if "--dryrun" in sys.argv:  # must precede the first jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import update as U
from repro.hypergraph import generators as GEN

MAXD, MAXR, CHUNK = 32, 1023, 2048


def dryrun(multi_pod: bool):
    """Thin wrapper over the engine's shared lowering (DESIGN.md §3.2)."""
    from repro.distributed import triads as DT
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_edges, region = 1_000_000, 1 << 16
    compiled, has_all_reduce = DT.lower_count_step(
        mesh, n_edges=n_edges, region=region, max_deg=32, chunk=4096)
    print(f"[escher dry-run] mesh={'2x16x16' if multi_pod else '16x16'} "
          f"edges={n_edges} region={region}: compiled OK")
    try:
        mem = compiled.memory_analysis()
        print(f"  arg={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB")
    except Exception:
        pass
    print(f"  collectives present: {has_all_reduce}")
    assert has_all_reduce, "psum merge missing from compiled HLO"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edges", type=int, default=2000)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--changes", type=int, default=100)
    ap.add_argument("--window", type=int, default=100)
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dryrun:
        dryrun(args.multi_pod)
        return

    nv = args.edges
    edges = GEN.random_hypergraph(args.edges, nv, profile="coauth",
                                  max_card=6, seed=0, skew=0.3)
    hg = H.from_lists(edges, num_vertices=nv, max_edges=4 * args.edges,
                      max_card=8, slack=4.0)
    n_slots = hg.n_edge_slots
    rng = np.random.default_rng(3)
    times = jnp.asarray(rng.integers(0, 1000, n_slots).astype(np.int32))

    counts = BL.mochy_static(hg, max_deg=MAXD, max_region=4 * args.edges - 1,
                             chunk=CHUNK)
    t_counts = BL.thyme_static(hg, times, args.window, max_deg=MAXD,
                               max_region=4 * args.edges - 1, chunk=CHUNK)
    print(f"initial: {int(counts.sum())} hyperedge triads, "
          f"{int(t_counts.sum())} temporal triads (δ={args.window})")

    for b in range(args.batches):
        present = np.asarray(hg.h2v.mgr.present)
        live = np.asarray(hg.h2v.mgr.hid)[present == 1]
        dels, ins = GEN.churn_batch(live, args.changes, 0.5, nv, 8,
                                    seed=10 + b, card_cap=6)
        nl, nc = GEN.pack_lists(ins, 8)
        dm = jnp.ones(len(dels), bool)
        im = jnp.ones(len(ins), bool)
        ins_t = jnp.asarray(
            rng.integers(1000 + b * 50, 1050 + b * 50, len(ins)).astype(np.int32))

        t0 = time.perf_counter()
        hg2, counts, _ = U.update_triad_counts(
            hg, counts, jnp.asarray(dels), dm, jnp.asarray(nl),
            jnp.asarray(nc), im, max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
        jax.block_until_ready(counts)
        dt_upd = time.perf_counter() - t0

        _, t_counts, times = U.update_triad_counts(
            hg, t_counts, jnp.asarray(dels), dm, jnp.asarray(nl),
            jnp.asarray(nc), im, max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
            temporal=True, times=times, ins_times=ins_t, window=args.window)
        hg = hg2

        t0 = time.perf_counter()
        ref = BL.mochy_static(hg, max_deg=MAXD, max_region=4 * args.edges - 1,
                              chunk=CHUNK)
        jax.block_until_ready(ref)
        dt_static = time.perf_counter() - t0
        ok = bool((np.asarray(counts) == np.asarray(ref)).all())
        print(f"batch {b}: update {dt_upd * 1e3:6.0f}ms  "
              f"recount {dt_static * 1e3:6.0f}ms  "
              f"speedup {dt_static / dt_upd:4.1f}x  exact={ok}  "
              f"triads={int(counts.sum())}  temporal={int(t_counts.sum())}")
        assert ok


if __name__ == "__main__":
    main()
