"""The paper's end-to-end workflow: maintain hyperedge-based, temporal and
incident-vertex triad counts through a stream of churn batches, timing the
incremental update against static recomputation.

    PYTHONPATH=src python examples/dynamic_triads.py [--edges 2000] [--batches 5]

``--dryrun`` instead lowers + compiles the *distributed* triad-count step
for the production meshes (DESIGN.md §3 "ESCHER at multi-pod scale"): the
(center, pair) probe work-list shards over (pod, data), the store replicates
per data-parallel group, and a scalar psum merges per-device histograms.
"""
import os
import sys

if "--dryrun" in sys.argv:  # must precede the first jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import update as U
from repro.hypergraph import generators as GEN

MAXD, MAXR, CHUNK = 32, 1023, 2048


def dryrun(multi_pod: bool):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import triads as T
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if multi_pod else ("data",)
    n_edges, max_card, max_deg, region = 1_000_000, 32, 32, 1 << 16

    # build the abstract (ShapeDtypeStruct) store directly — no allocation
    import repro.core.blockmgr as bm
    import repro.core.store as ST
    h = bm.tree_height(n_edges)
    size = 1 << (h + 1)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    mgr = bm.BlockManager(hid=i32(size), addr0=i32(size), cap0=i32(size),
                          addr1=i32(size), cap1=i32(size), card=i32(size),
                          present=i32(size), deleted=i32(size),
                          avail=i32(size), height=h)
    store = ST.EscherStore(A=i32(n_edges * 64), mgr=mgr, free_ptr=i32(),
                           n_ranks=i32(), error=i32(), granule=32,
                           max_card=max_card)
    vmgr_h = bm.tree_height(n_edges // 2)
    vsize = 1 << (vmgr_h + 1)
    vmgr = bm.BlockManager(hid=i32(vsize), addr0=i32(vsize), cap0=i32(vsize),
                           addr1=i32(vsize), cap1=i32(vsize), card=i32(vsize),
                           present=i32(vsize), deleted=i32(vsize),
                           avail=i32(vsize), height=vmgr_h)
    vstore = ST.EscherStore(A=i32(n_edges * 64), mgr=vmgr, free_ptr=i32(),
                            n_ranks=i32(), error=i32(), granule=32,
                            max_card=64)
    hg = H.Hypergraph(h2v=store, v2h=vstore)

    def count_step(hg, region_ranks, region_mask):
        return T.count_triads(hg, region_ranks, region_mask,
                              max_deg=max_deg, chunk=4096)

    rep = NamedSharding(mesh, P())
    shard = NamedSharding(mesh, P(dp))
    hg_sh = jax.tree_util.tree_map(lambda _: rep, hg)
    with mesh:
        lowered = jax.jit(
            count_step,
            in_shardings=(hg_sh, shard, shard),
            out_shardings=rep,
        ).lower(hg, i32(region), jax.ShapeDtypeStruct((region,), jnp.bool_))
        compiled = lowered.compile()
        print(f"[escher dry-run] mesh={'2x16x16' if multi_pod else '16x16'} "
              f"edges={n_edges} region={region}: compiled OK")
        try:
            mem = compiled.memory_analysis()
            print(f"  arg={mem.argument_size_in_bytes/1e9:.2f}GB "
                  f"temp={mem.temp_size_in_bytes/1e9:.2f}GB")
        except Exception:
            pass
        print(f"  collectives present: "
              f"{'all-reduce' in compiled.as_text()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edges", type=int, default=2000)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--changes", type=int, default=100)
    ap.add_argument("--window", type=int, default=100)
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dryrun:
        dryrun(args.multi_pod)
        return

    nv = args.edges
    edges = GEN.random_hypergraph(args.edges, nv, profile="coauth",
                                  max_card=6, seed=0, skew=0.3)
    hg = H.from_lists(edges, num_vertices=nv, max_edges=4 * args.edges,
                      max_card=8, slack=4.0)
    n_slots = hg.n_edge_slots
    rng = np.random.default_rng(3)
    times = jnp.asarray(rng.integers(0, 1000, n_slots).astype(np.int32))

    counts = BL.mochy_static(hg, max_deg=MAXD, max_region=4 * args.edges - 1,
                             chunk=CHUNK)
    t_counts = BL.thyme_static(hg, times, args.window, max_deg=MAXD,
                               max_region=4 * args.edges - 1, chunk=CHUNK)
    print(f"initial: {int(counts.sum())} hyperedge triads, "
          f"{int(t_counts.sum())} temporal triads (δ={args.window})")

    for b in range(args.batches):
        present = np.asarray(hg.h2v.mgr.present)
        live = np.asarray(hg.h2v.mgr.hid)[present == 1]
        dels, ins = GEN.churn_batch(live, args.changes, 0.5, nv, 8,
                                    seed=10 + b, card_cap=6)
        nl, nc = GEN.pack_lists(ins, 8)
        dm = jnp.ones(len(dels), bool)
        im = jnp.ones(len(ins), bool)
        ins_t = jnp.asarray(
            rng.integers(1000 + b * 50, 1050 + b * 50, len(ins)).astype(np.int32))

        t0 = time.perf_counter()
        hg2, counts, _ = U.update_triad_counts(
            hg, counts, jnp.asarray(dels), dm, jnp.asarray(nl),
            jnp.asarray(nc), im, max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
        jax.block_until_ready(counts)
        dt_upd = time.perf_counter() - t0

        _, t_counts, times = U.update_triad_counts(
            hg, t_counts, jnp.asarray(dels), dm, jnp.asarray(nl),
            jnp.asarray(nc), im, max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
            temporal=True, times=times, ins_times=ins_t, window=args.window)
        hg = hg2

        t0 = time.perf_counter()
        ref = BL.mochy_static(hg, max_deg=MAXD, max_region=4 * args.edges - 1,
                              chunk=CHUNK)
        jax.block_until_ready(ref)
        dt_static = time.perf_counter() - t0
        ok = bool((np.asarray(counts) == np.asarray(ref)).all())
        print(f"batch {b}: update {dt_upd * 1e3:6.0f}ms  "
              f"recount {dt_static * 1e3:6.0f}ms  "
              f"speedup {dt_static / dt_upd:4.1f}x  exact={ok}  "
              f"triads={int(counts.sum())}  temporal={int(t_counts.sum())}")
        assert ok


if __name__ == "__main__":
    main()
