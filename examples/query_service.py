"""Interleaved stream ingest + query traffic against the live store.

Demonstrates the triad query service (src/repro/query/, DESIGN.md §7):
a hyperedge event stream drains through the incremental engine while
batched point queries (per-edge / per-vertex triad participation), top-k
triplet retrieval, and O(1) histogram reads are served from epoch-stamped
snapshots — with the per-edge cache invalidated only where churn actually
landed.  Final answers are verified against fresh recounts.

    PYTHONPATH=src python examples/query_service.py [--events 240] [--batch 16]
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import hypergraph as H
from repro.core import motifs
from repro.core import stream as S
from repro.core import triads as T
from repro.hypergraph import generators as GEN
from repro import query

MAXD, MAXNB, MAXR, CHUNK = 32, 32, 511, 1024


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=240)
    ap.add_argument("--vertices", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--queries-per-round", type=int, default=24)
    ap.add_argument("--topk", type=int, default=5)
    args = ap.parse_args()

    nv = args.vertices
    events = GEN.event_stream(args.events, nv, profile="coauth",
                              insert_frac=0.8, seed=0, max_card=6, max_dt=2)
    hg = H.from_lists([], num_vertices=nv, max_edges=4 * args.events,
                      max_card=8, max_vdeg=64, min_capacity=64 * args.events)
    st = S.make_stream(hg, S.log_from_events(events, max_card=8),
                       jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    n_steps = S.plan_steps(events, args.batch)
    run_kw = dict(batch=args.batch, mode="edge", max_deg=MAXD, max_nb=MAXNB,
                  max_region=MAXR, chunk=CHUNK)
    serve_kw = dict(max_deg=MAXD, max_nb=MAXNB, max_region=MAXR, chunk=CHUNK)

    cache = query.QueryCache()
    rng = np.random.default_rng(1)
    print(f"stream: {len(events)} events, {n_steps} scheduler steps of "
          f"batch {args.batch}; query traffic between every 2 steps")

    done = 0
    while done < n_steps:
        step = min(2, n_steps - done)
        st = S.run_stream(st, n_steps=step, **run_kw)   # ingest keeps moving
        done += step

        snap = query.of_stream(st)                      # O(1): refs + epoch
        live = H.live_ranks_host(snap.hg)
        if len(live) == 0:
            continue
        reqs = [query.triads_containing_edge(int(r))
                for r in rng.choice(live, args.queries_per_round)]
        reqs += [query.triads_at_vertex(int(v))
                 for v in rng.integers(0, nv, 4)]
        reqs += [query.topk_triplets(args.topk), query.histogram()]

        t0 = time.perf_counter()
        out = query.serve(snap, reqs, cache=cache, v_total=nv, **serve_kw)
        dt = (time.perf_counter() - t0) * 1e3
        n_dirty = int((np.asarray(st.dirty_epoch) == snap.epoch).sum())
        top = out[-2]
        best = (f"best|a∩b∩c|={int(top.scores[0])}"
                if np.any(np.asarray(top.valid)) else "no triples yet")
        print(f"  epoch {snap.epoch:3d}: live={len(live):3d} "
              f"dirty_last_batch={n_dirty:3d} "
              f"served {len(reqs):2d} queries in {dt:6.1f} ms "
              f"(cache {cache.hits}h/{cache.misses}m) {best}")

    # verify the last round's battery against fresh recounts
    snap = query.of_stream(st)
    live = H.live_ranks_host(snap.hg)
    probe = [int(r) for r in live[:8]]
    out = query.serve(snap, [query.triads_containing_edge(r) for r in probe],
                      cache=cache, v_total=nv, **serve_kw)
    for j, r in enumerate(probe):
        ref = T.count_triads_containing(
            snap.hg, jnp.asarray([r], jnp.int32), jnp.ones(1, bool),
            max_deg=MAXD, chunk=CHUNK)
        assert (out[j] == np.asarray(ref)).all(), r
    print(f"final epoch {snap.epoch}: {len(probe)} cached answers verified "
          f"against fresh recounts; hit rate {cache.hit_rate():.0%}")


if __name__ == "__main__":
    main()
