"""Live triad counts over a synthetic hyperedge event stream.

Demonstrates the streaming evolution engine (core/stream.py, DESIGN.md §5):
a timestamped insert/delete event log is coalesced into churn batches and
scanned through the Alg. 3 incremental core, keeping hyperedge-based and
temporal (sliding δ-window, with retention expiry) triad counts current.
Final counts are verified against from-scratch recounts.

With ``--unbounded`` the stores start at *minimal* capacity and the run
relies on ``run_stream(auto_grow=True)`` (DESIGN.md §8): the segmented
driver detects capacity / rank-space exhaustion at segment boundaries,
compacts or doubles the stores (core/elastic.py), and resumes — the final
counts are still exact, and the growth journey is printed.

    PYTHONPATH=src python examples/streaming.py [--events 300] [--batch 16]
    PYTHONPATH=src python examples/streaming.py --unbounded
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import motifs
from repro.core import stream as S
from repro.hypergraph import generators as GEN

MAXD, MAXR, CHUNK = 32, 511, 1024


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=300)
    ap.add_argument("--vertices", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--window", type=int, default=60, help="temporal triad δ")
    ap.add_argument("--expiry", type=int, default=120,
                    help="retention window: older inserts auto-delete")
    ap.add_argument("--report-every", type=int, default=4,
                    help="print live counts every N scheduler steps")
    ap.add_argument("--unbounded", action="store_true",
                    help="start at minimal capacity and auto-grow "
                         "(run_stream(auto_grow=True), DESIGN.md §8)")
    args = ap.parse_args()

    nv = args.vertices
    events = GEN.event_stream(args.events, nv, profile="coauth",
                              insert_frac=0.75, seed=0, max_card=6, max_dt=2)
    if not events:
        print("empty stream: nothing to do")
        return
    n_ins = sum(1 for _, k, _ in events if k == "ins")
    print(f"stream: {len(events)} events ({n_ins} ins, "
          f"{len(events) - n_ins} del), t ∈ [0, {max(t for t, _, _ in events)}]")

    if args.unbounded:
        # deliberately undersized: ~one granule of memory and an 8-rank
        # tree — everything past that is auto_grow's problem
        hg = H.from_lists([], num_vertices=nv, max_edges=8, max_card=8,
                          max_vdeg=64, granule=32, slack=1.0)
        print(f"unbounded mode: starting at h2v capacity "
              f"{hg.h2v.capacity}, {hg.n_edge_slots} rank slots")
    else:
        hg = H.from_lists([], num_vertices=nv, max_edges=4 * args.events,
                          max_card=8, max_vdeg=64,
                          min_capacity=64 * args.events)
    log = S.log_from_events(events, max_card=8)
    edge = S.make_stream(hg, log, jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    temp = S.make_stream(hg, S.log_from_events(events, max_card=8),
                         jnp.zeros(motifs.NUM_TEMPORAL, jnp.int32))

    grow_log: list[dict] = []          # edge-mode repairs (reported below)
    temp_grow_log: list[dict] = []     # temporal-mode repairs
    kw = dict(batch=args.batch, max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
              auto_grow=args.unbounded)
    n_edge = S.plan_steps(events, args.batch)
    n_temp = S.plan_steps(events, args.batch, expiry=args.expiry)

    # --- live hyperedge-based counts, reported as the stream drains
    t0 = time.perf_counter()
    done = 0
    while done < n_edge:
        step = min(args.report_every, n_edge - done)
        edge = S.run_stream(edge, n_steps=step, mode="edge",
                            grow_log=grow_log, **kw)
        done += step
        jax.block_until_ready(edge.counts)
        print(f"  step {done:3d}/{n_edge}: live={int(edge.hg.h2v.n_live):4d} "
              f"triads={int(edge.counts.sum()):6d} t={int(edge.t_now):4d}")
    dt = time.perf_counter() - t0
    print(f"hyperedge mode: {len(events) / dt:,.0f} events/sec "
          f"(incl. per-report sync)")
    if args.unbounded:
        for g in grow_log:
            print(f"  grew at epoch {g['epoch']}: "
                  f"h2v cap={g['h2v_capacity']} height={g['h2v_height']}, "
                  f"v2h cap={g['v2h_capacity']}")
        print(f"  {len(grow_log)} repairs; final h2v capacity "
              f"{edge.hg.h2v.capacity} ({edge.hg.n_edge_slots} rank slots)")

    # --- temporal counts with retention expiry, one fused scan
    t0 = time.perf_counter()
    temp = S.run_stream(temp, n_steps=n_temp, mode="temporal",
                        window=args.window, expiry=args.expiry,
                        grow_log=temp_grow_log, **kw)
    jax.block_until_ready(temp.counts)
    dt = time.perf_counter() - t0
    grew = (f", {len(temp_grow_log)} repairs (final cap "
            f"{temp.hg.h2v.capacity})" if temp_grow_log else "")
    print(f"temporal mode (δ={args.window}, expiry={args.expiry}): "
          f"{len(events) / dt:,.0f} events/sec, live={int(temp.hg.h2v.n_live)}, "
          f"temporal triads={int(temp.counts.sum())}{grew}")

    # --- verify against from-scratch recounts
    ref_e = BL.mochy_static(edge.hg, max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    ref_t = BL.thyme_static(temp.hg, temp.times, args.window,
                            max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    ok_e = bool((np.asarray(edge.counts) == np.asarray(ref_e)).all())
    ok_t = bool((np.asarray(temp.counts) == np.asarray(ref_t)).all())
    errs = S.decode_errors(edge) + S.decode_errors(temp)
    print(f"exact vs recount: hyperedge={ok_e} temporal={ok_t} "
          f"sticky_errors={[(e.name, e.epoch) for e in errs] or 'none'}")
    assert ok_e and ok_t and not errs


if __name__ == "__main__":
    main()
