"""End-to-end LM training example: a ~100M-class reduced config for a few
hundred steps through the fault-tolerant driver (checkpoint/restart, elastic
data sharding, optional int8+EF gradient compression).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    argv = [
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--inject-fail-at", str(args.steps // 2),  # prove the restart path
    ]
    if args.compress_grads:
        argv.append("--compress-grads")
    losses = train_main(argv)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps "
          f"(survived 1 injected failure)")


if __name__ == "__main__":
    main()
