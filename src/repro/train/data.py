"""Deterministic sharded synthetic data pipeline.

Every host derives its shard of the global batch purely from
``(seed, step, host_id)`` — no coordination, bitwise reproducible, and a
restarted/rescaled job regenerates exactly the batches it would have seen
(the elastic-reshard property tested in tests/test_fault.py).  Tokens follow
a zipf-ish distribution so the CE loss has realistic structure.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _host_slice(global_batch: int, host_id: int, num_hosts: int) -> tuple[int, int]:
    per = global_batch // num_hosts
    return host_id * per, per


def host_batch(cfg: DataConfig, step: int, host_id: int = 0, num_hosts: int = 1,
               arch: ArchConfig | None = None) -> dict:
    """This host's slice of the global batch for ``step`` (numpy, host-side)."""
    start, count = _host_slice(cfg.global_batch, host_id, num_hosts)
    out_tokens = np.empty((count, cfg.seq_len), np.int32)
    for i in range(count):
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, start + i]))
        # zipf-flavoured ids, clipped to vocab
        z = rng.zipf(1.3, size=cfg.seq_len + 1).astype(np.int64)
        toks = (z % cfg.vocab).astype(np.int32)
        out_tokens[i] = toks[:-1]
        if i == 0:
            labels_shape = None
    tokens = out_tokens
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    batch = dict(tokens=tokens, labels=labels)
    if arch is not None and arch.family == "vlm":
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, 10**6]))
        batch["image_embeds"] = rng.standard_normal(
            (count, arch.vision_tokens, arch.vision_embed_dim)).astype(np.float32)
    if arch is not None and arch.family == "audio":
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, 10**6 + 1]))
        batch["audio_feats"] = rng.standard_normal(
            (count, cfg.seq_len, arch.audio_feat_dim)).astype(np.float32)
    return batch


def global_batch(cfg: DataConfig, step: int, arch: ArchConfig | None = None) -> dict:
    """Whole global batch (single-host testing path)."""
    return host_batch(cfg, step, 0, 1, arch)
