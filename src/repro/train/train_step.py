"""Training step factory: loss, grad accumulation (microbatches), AdamW,
optional int8 gradient compression with error feedback.

The returned ``train_step(state, batch)`` is a pure function suitable for
``jax.jit`` with donated state; the microbatch loop is a ``lax.scan`` so the
HLO stays compact and XLA overlaps the per-microbatch gradient all-reduce
with the next microbatch's backward pass (latency hiding).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.train import optimizer as OPT
from repro.distributed import compression as COMP


def cross_entropy(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = logz - gold
    if mask is not None:
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(ce)


def make_loss_fn(cfg: ArchConfig, *, remat=True):
    def loss_fn(params, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["image_embeds"] = batch["image_embeds"]
        if cfg.family == "audio":
            kw["audio_feats"] = batch["audio_feats"]
        logits, _, aux = api.forward(cfg, params, batch["tokens"], remat=remat, **kw) \
            if cfg.family not in ("ssm",) else api.forward(cfg, params, batch["tokens"], **kw)
        ce = cross_entropy(logits, batch["labels"])
        return ce + 0.01 * aux, dict(ce=ce, aux=aux)

    return loss_fn


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: OPT.AdamWConfig,
    *,
    microbatches: int = 1,
    compress_grads: bool = False,
    remat=True,
):
    loss_fn = make_loss_fn(cfg, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params, opt = state["params"], state["opt"]
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def body(acc, one):
                (loss, aux), g = grad_fn(params, one)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return acc, (loss, aux["ce"])

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ces) = jax.lax.scan(body, zeros, mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss, ce = jnp.mean(losses), jnp.mean(ces)
        else:
            (loss, aux), grads = grad_fn(params, batch)
            ce = aux["ce"]

        ef = state.get("ef")
        if compress_grads:
            grads, ef = COMP.compress_decompress(grads, ef)

        new_params, new_opt, om = OPT.apply_updates(opt_cfg, params, opt, grads)
        new_state = dict(params=new_params, opt=new_opt, step=state["step"] + 1)
        if compress_grads:
            new_state["ef"] = ef
        metrics = dict(loss=loss, ce=ce, **om)
        return new_state, metrics

    return train_step


def init_train_state(cfg: ArchConfig, key, dtype=jnp.float32, *, compress_grads=False):
    params, specs = api.init_params(cfg, key, dtype)
    state = dict(params=params, opt=OPT.init_state(params), step=jnp.zeros((), jnp.int32))
    if compress_grads:
        state["ef"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state, specs


def state_shardings(specs, state, mode, mesh):
    """Sharding tree matching a train state (opt moments follow params)."""
    from repro.distributed import sharding as SH
    from jax.sharding import NamedSharding, PartitionSpec as P

    p_sh = SH.param_shardings(specs, state["params"], mode, mesh)
    rep = NamedSharding(mesh, P())
    out = dict(
        params=p_sh,
        opt=dict(m=dict(p_sh), v=dict(p_sh), step=rep),
        step=rep,
    )
    if "ef" in state:
        out["ef"] = dict(p_sh)
    return out
