"""AdamW + global-norm clipping + WSD/cosine schedule — pure JAX pytrees.

Optimizer state mirrors the param tree (m, v in f32 regardless of param
dtype — mixed-precision master moments), so the same sharding specs apply
leaf-for-leaf and FSDP shards the moments too.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(c: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - c.warmup_steps) / jnp.maximum(c.total_steps - c.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * warm * cos


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(tree))
    )


def _decay_mask(path: str) -> bool:
    """No weight decay on norms / biases / scalars."""
    needle = path.lower()
    return not any(t in needle for t in ("norm", "ln", "bias", "gate", "mu", "w0", "u"))


def apply_updates(c: AdamWConfig, params: dict, opt: dict, grads: dict):
    """One AdamW step. Returns (params', opt', metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / (gnorm + 1e-6))
    step = opt["step"] + 1
    lr = schedule(c, step)
    b1, b2 = c.beta1, c.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k].astype(jnp.float32) * scale
        m = b1 * opt["m"][k] + (1 - b1) * g
        v = b2 * opt["v"][k] + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + c.eps)
        if _decay_mask(k):
            upd = upd + c.weight_decay * params[k].astype(jnp.float32)
        new_p[k] = (params[k].astype(jnp.float32) - lr * upd).astype(params[k].dtype)
        new_m[k] = m
        new_v[k] = v
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, dict(m=new_m, v=new_v, step=step), metrics
