"""Sharded checkpointing with atomic two-phase commit.

Layout:
    <dir>/step_000123/
        shard_00000.npz     flat {path -> array} (this host's shards)
        MANIFEST.json       step, shard list, tree structure, digest
    <dir>/LATEST            text file naming the last *complete* step dir

Protocol: write shards → write MANIFEST.json → atomically rename the temp
dir to its final name → rewrite LATEST.  A crash at any point leaves either
a complete checkpoint or an ignorable ``*.tmp`` directory — restart always
resumes from a consistent step (tests/test_fault.py kills mid-write).

Elastic reshard: arrays are saved *unsharded per leaf* (host gathers its
addressable shards; on CPU/test scale the leaf is whole).  Restoring onto a
different mesh/data-parallel size just re-shards via device_put — the
checkpoint format is topology-free.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np

SEP = "|"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{SEP}" if prefix or True else k))
        return out
    out[prefix.removesuffix(SEP)] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for path, v in flat.items():
        keys = path.split(SEP)
        node = tree
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = v
    return tree


def save(ckpt_dir: str, step: int, state) -> str:
    flat = _flatten(state)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
    manifest = dict(
        step=step,
        time=time.time(),
        shards=["shard_00000.npz"],
        keys=sorted(arrays.keys()),
        sizes={k: int(a.size) for k, a in arrays.items()},
    )
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str):
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            name = f.read().strip()
        path = os.path.join(ckpt_dir, name)
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        return manifest["step"], path
    except (FileNotFoundError, json.JSONDecodeError):
        return None, None


def restore(ckpt_dir: str, *, shardings=None):
    """Returns (step, state) from the last complete checkpoint, or (None,
    None).  ``shardings``: optional matching tree of NamedShardings — the
    elastic-reshard path (device_put onto the new mesh)."""
    step, path = latest_step(ckpt_dir)
    if step is None:
        return None, None
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    flat = {}
    for shard in manifest["shards"]:
        with np.load(os.path.join(path, shard)) as z:
            for k in z.files:
                flat[k] = z[k]
    state = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        state = _unflatten({
            k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
            for k, v in _flatten(state).items()
        })
    return step, state


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    for d in os.listdir(ckpt_dir):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
