"""Fault tolerance: restartable training driver + failure injection.

At 1000+ nodes the mean time between node failures is shorter than a long
run, so the driver assumes steps CAN throw at any point and recovers:

  * checkpoint every ``ckpt_every`` steps (atomic, see checkpoint.py);
  * on failure, rebuild state from the last complete checkpoint and replay
    (the data pipeline is a pure function of step → identical batches);
  * bounded retries per step guard against deterministic poison;
  * straggler mitigation hook: ``step_timeout`` wraps the step with a
    watchdog — on real clusters this triggers the synchronous-rewind path
    (here it raises, exercising the same restart machinery);
  * elastic rescale: ``restore`` accepts a different device topology — the
    checkpoint is topology-free and batches are derived from (step, host),
    so changing the data-parallel width mid-run is a restart, not a redo.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

from repro.train import checkpoint as CKPT

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_retries_per_step: int = 3
    step_timeout_s: Optional[float] = None


class StepTimeout(RuntimeError):
    pass


def run_loop(
    *,
    init_state_fn: Callable[[], dict],
    train_step: Callable[[dict, dict], tuple[dict, dict]],
    batch_fn: Callable[[int], dict],
    total_steps: int,
    fault: FaultConfig,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
    failure_injector: Optional[Callable[[int], None]] = None,
) -> dict:
    """Drive training to ``total_steps`` surviving injected/real failures.
    Returns the final state."""
    step, state = CKPT.restore(fault.ckpt_dir)
    if state is None:
        state, step = init_state_fn(), 0
        CKPT.save(fault.ckpt_dir, 0, state)
    else:
        log.info("restored checkpoint at step %d", step)

    retries = 0
    while step < total_steps:
        try:
            if failure_injector is not None:
                failure_injector(step)  # may raise — simulated node loss
            t0 = time.time()
            batch = batch_fn(step)
            state, metrics = train_step(state, batch)
            if fault.step_timeout_s is not None and (time.time() - t0) > fault.step_timeout_s:
                raise StepTimeout(f"step {step} exceeded {fault.step_timeout_s}s")
            step += 1
            retries = 0
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % fault.ckpt_every == 0 or step == total_steps:
                CKPT.save(fault.ckpt_dir, step, state)
                CKPT.gc_old(fault.ckpt_dir, fault.keep)
        except Exception as e:  # noqa: BLE001 — the whole point
            retries += 1
            log.warning("step %d failed (%s); restore+retry %d/%d",
                        step, e, retries, fault.max_retries_per_step)
            if retries > fault.max_retries_per_step:
                raise
            r_step, r_state = CKPT.restore(fault.ckpt_dir)
            if r_state is None:
                raise
            step, state = r_step, r_state
    return state
