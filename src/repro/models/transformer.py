"""Decoder/encoder transformer covering the dense, MoE, VLM and audio
families.  Layers are stacked ([L, ...] params) and executed with
``jax.lax.scan`` + activation remat so the HLO stays compact for 64-100
layer configs and the dry-run compiles quickly.

VLM (llama-3.2-vision style): layers are organised in groups of
``cross_attn_every`` self layers followed by one cross-attention layer
reading projected image-patch embeddings; scan over groups with an inner
scan over the group's self layers.

Caches: self-attn KV per layer stacked [L, B, K, S_max, hd]; cross-attn KV
is computed once at prefill.  ``positions`` are absolute token positions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as LYR
from repro.models import moe as MOE
from repro.models.layers import (EMBED, HEADS, KV, LAYER, NONE, VOCAB,
                                 ParamBuilder, attention, attention_params,
                                 mlp, mlp_params, rms_norm, take_layer)


def n_groups(cfg: ArchConfig) -> int:
    if cfg.cross_attn_every:
        return cfg.n_layers // (cfg.cross_attn_every + 1)
    return 0


def n_self_layers(cfg: ArchConfig) -> int:
    g = n_groups(cfg)
    return cfg.n_layers - g if g else cfg.n_layers


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    b = ParamBuilder(key, dtype)
    D, V = cfg.d_model, cfg.vocab
    Ls = n_self_layers(cfg)
    b.add("embed", (V, D), (VOCAB, EMBED), scale=0.02)
    attention_params(b, cfg, "self/", Ls)
    if cfg.n_experts:
        MOE.moe_params(b, cfg, "self/", Ls)
    else:
        mlp_params(b, cfg, "self/", Ls)
    b.add("self/ln1", (Ls, D), (LAYER, EMBED), ones=True)
    b.add("self/ln2", (Ls, D), (LAYER, EMBED), ones=True)
    g = n_groups(cfg)
    if g:
        attention_params(b, cfg, "cross/", g)
        mlp_params(b, cfg, "cross/", g)
        b.add("cross/ln1", (g, D), (LAYER, EMBED), ones=True)
        b.add("cross/ln2", (g, D), (LAYER, EMBED), ones=True)
        b.add("cross/gate", (g,), (LAYER,), zeros=True)
        b.add("vision_proj", (cfg.vision_embed_dim, D), (NONE, EMBED))
    if cfg.audio_feat_dim:
        b.add("audio_proj", (cfg.audio_feat_dim, D), (NONE, EMBED))
    b.add("final_norm", (D,), (EMBED,), ones=True)
    if not cfg.tie_embeddings:
        b.add("lm_head", (D, V), (EMBED, VOCAB), scale=0.02)
    return b.params, b.specs


def _self_block(cfg: ArchConfig, lp: dict, x, positions, cache, cache_pos, layer_window):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, new_cache = attention(
        lp, cfg, h, positions,
        cache=cache, cache_pos=cache_pos,
        causal=not cfg.encoder_only,
        window=layer_window,
    )
    x = x + attn_out
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, aux = MOE.moe_apply(lp, cfg, h)
    else:
        ff, aux = mlp(lp, h), jnp.float32(0.0)
    return x + ff, new_cache, aux


def _window_for_layer(cfg: ArchConfig, i):
    """Hybrid archs: sliding window except every k-th (global) layer."""
    if cfg.sliding_window is None:
        return None
    if cfg.global_layer_every:
        # traced layer index: window as dynamic value (None only when static)
        is_global = (i % cfg.global_layer_every) == 0
        return jnp.where(is_global, jnp.int32(1 << 30), jnp.int32(cfg.sliding_window))
    return cfg.sliding_window


def make_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    Ls = n_self_layers(cfg)
    K, hd = cfg.n_kv_heads, cfg.hd
    shape = (Ls, batch, K, max_seq, hd)
    return dict(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def _remat_wrap(body, remat):
    """remat=True: full recompute; remat="dots": save matmul outputs and
    recompute only elementwise chains (≈-25% HBM traffic for one extra
    microbatch-lifetime of saved dots — §Perf iteration M2); False: none."""
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body) if remat else body


@functools.partial(jax.jit, static_argnames=("cfg", "remat"))
def forward(
    cfg: ArchConfig,
    params: dict,
    tokens: jax.Array,                     # int32[B, S]
    *,
    positions: Optional[jax.Array] = None, # int32[S]
    image_embeds: Optional[jax.Array] = None,  # [B, N_img, vision_embed_dim]
    audio_feats: Optional[jax.Array] = None,   # [B, S, feat]
    cache: Optional[dict] = None,          # stacked KV cache
    cache_pos: Optional[jax.Array] = None,
    remat: bool = True,
):
    """Returns (logits [B,S,V], new_cache, aux_loss)."""
    if audio_feats is not None:
        x = audio_feats.astype(params["embed"].dtype) @ params["audio_proj"]
    else:
        x = params["embed"][tokens]
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)

    Ls = n_self_layers(cfg)
    g = n_groups(cfg)
    img = None
    if g and image_embeds is not None:
        img = image_embeds.astype(x.dtype) @ params["vision_proj"]

    self_params = {k.removeprefix("self/"): v for k, v in params.items()
                   if k.startswith("self/")}

    def layer_body(carry, inputs):
        x = carry
        lp, idx, cache_l = inputs
        win = _window_for_layer(cfg, idx)
        x, new_cache_l, aux = _self_block(
            cfg, lp, x, positions, cache_l, cache_pos, win)
        return x, (new_cache_l, aux)

    body = _remat_wrap(layer_body, remat)

    def run_stack(x, stack_params, stack_cache, idx0):
        nl = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
        idxs = idx0 + jnp.arange(nl)
        x, (new_cache, aux) = jax.lax.scan(
            body, x, (stack_params, idxs, stack_cache))
        return x, new_cache, jnp.sum(aux)

    if not g:
        x, new_cache, aux = run_stack(x, self_params, cache, 0)
    else:
        # groups: cross_attn_every self layers + 1 cross layer
        k_in = cfg.cross_attn_every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((g, k_in) + a.shape[1:]), self_params)
        cross_params = {k.removeprefix("cross/"): v for k, v in params.items()
                        if k.startswith("cross/")}
        cache_g = (jax.tree_util.tree_map(
            lambda a: a.reshape((g, k_in) + a.shape[1:]), cache)
            if cache is not None else None)

        def group_body(carry, inputs):
            x = carry
            gp, cp, gidx, gcache = inputs
            x, new_gcache, aux = run_stack(x, gp, gcache, gidx * k_in)
            # cross-attention layer (full attn over image tokens, gated)
            h = rms_norm(x, cp["ln1"], cfg.norm_eps)
            ca, _ = attention(cp, cfg, h, positions, kv_x=img, causal=False,
                              use_rope=False)
            x = x + jnp.tanh(cp["gate"]) * ca
            h = rms_norm(x, cp["ln2"], cfg.norm_eps)
            x = x + mlp(cp, h)
            return x, (new_gcache, aux)

        gbody = _remat_wrap(group_body, remat)
        x, (new_cache_g, aux_g) = jax.lax.scan(
            gbody, x, (grouped, cross_params, jnp.arange(g), cache_g))
        new_cache = (jax.tree_util.tree_map(
            lambda a: a.reshape((g * k_in,) + a.shape[2:]), new_cache_g)
            if cache is not None else None)
        aux = jnp.sum(aux_g)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, new_cache, aux
