"""Mixture-of-Experts FFN with sort-based (dropless-capacity) dispatch.

TPU/SPMD-friendly formulation: token→expert assignments are sorted by
expert, packed into a fixed [E, C, D] buffer (C = capacity), experts run as
one grouped einsum with the expert axis sharded over "model" (EP), and
results scatter back with combine weights.  Overflow beyond capacity is
dropped (standard GShard/Switch semantics; capacity_factor controls it).

The argsort/gather/scatter formulation avoids the O(T·E·C) one-hot dispatch
tensors of the classic Mesh-TF implementation — at 1M-token batches those
are unmaterialisable — and lets XLA SPMD turn the resharding into
all-to-all-style collectives on the EP axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import EMBED, EXPERT, FFN, LAYER, NONE, ParamBuilder


def moe_params(b: ParamBuilder, cfg: ArchConfig, prefix: str, layers: int):
    D, F, E, L = cfg.d_model, cfg.d_ff, cfg.n_experts, layers
    # router is tiny (D×E) and every EP rank needs all logits -> replicated
    b.add(f"{prefix}router", (L, D, E), (LAYER, EMBED, NONE))
    b.add(f"{prefix}w_gate", (L, E, D, F), (LAYER, EXPERT, EMBED, FFN))
    b.add(f"{prefix}w_up", (L, E, D, F), (LAYER, EXPERT, EMBED, FFN))
    b.add(f"{prefix}w_down", (L, E, F, D), (LAYER, EXPERT, FFN, EMBED))


def capacity(tokens: int, cfg: ArchConfig) -> int:
    c = int(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(128, -(-c // 128) * 128)  # round up to lane multiple


def moe_apply(p: dict, cfg: ArchConfig, x: jax.Array):
    """x: [B, S, D] -> (y, aux_loss).  Router in f32 for stability.

    When a production mesh is registered (distributed/context.py) the EP
    shard_map path runs instead: under TP the activations are replicated
    across "model", so every expert shard dispatches *locally* and one psum
    combines — no cross-shard scatter.  The portable XLA-global path below
    is what single-device tests and tiny smoke configs use; at scale XLA
    lowers its cross-sharding scatter to replicated-buffer all-reduces
    (measured 18.6 TB/device/step on moonshot train_4k — §Perf iteration M1).
    """
    from repro.distributed import context as CTX
    mesh = CTX.current_mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and cfg.n_experts % mesh.shape["model"] == 0:
        return _moe_apply_ep(p, cfg, x, mesh)
    return _moe_apply_global(p, cfg, x)


def _moe_apply_ep(p: dict, cfg: ArchConfig, x: jax.Array, mesh):
    """Expert-parallel dispatch via shard_map (DESIGN.md §3).

    Device (d, m): holds tokens of data-shard d (replicated over model) and
    the experts of group m.  Local top-k selects which of *my* experts each
    local token hits; tokens routed to other groups contribute zero here and
    are produced by the owning group — the final psum("model") merges.
    Per-(shard, expert) capacity = global capacity / data-shards.
    """
    from jax.sharding import PartitionSpec as P
    from repro.distributed.context import dp_axes

    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    dp = dp_axes(mesh)
    n_data = 1
    for a in dp:
        n_data *= mesh.shape[a]
    C_loc = max(8, capacity(T, cfg) // n_data)

    def block(xf, router, w_gate, w_up, w_down):
        # xf [T_l, D]; router [D, E] replicated; w_* [E_l, D, F]
        T_l = xf.shape[0]
        E_l = w_gate.shape[0]
        m_idx = jax.lax.axis_index("model")
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        # aux (computed once per model rank; psum-mean below)
        disp = jnp.sum(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), 0) / T_l
        aux = E * jnp.sum(disp * jnp.mean(probs, axis=0))

        # keep only assignments that land in MY expert group
        lo = m_idx * E_l
        e_flat = top_i.reshape(-1)
        mine = (e_flat >= lo) & (e_flat < lo + E_l)
        e_loc = jnp.where(mine, e_flat - lo, E_l)          # E_l = drop bucket
        w_flat = jnp.where(mine, top_p.reshape(-1), 0.0)
        order = jnp.argsort(e_loc)
        e_sorted = e_loc[order]
        tok_sorted = (order // k).astype(jnp.int32)
        first = jnp.searchsorted(e_sorted, e_sorted, side="left")
        slot = (jnp.arange(T_l * k, dtype=jnp.int32) - first)
        keep = (slot < C_loc) & (e_sorted < E_l)
        dest = jnp.where(keep, e_sorted * C_loc + slot, E_l * C_loc)

        buf = jnp.zeros((E_l * C_loc, D), x.dtype).at[dest].set(
            xf[tok_sorted], mode="drop").reshape(E_l, C_loc, D)
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up)
        h = jax.nn.silu(g) * u
        out = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_l * C_loc, D)

        back = out[jnp.minimum(dest, E_l * C_loc - 1)] * keep[:, None]
        contrib = back * w_flat[order][:, None].astype(x.dtype)
        y = jnp.zeros((T_l, D), x.dtype).at[tok_sorted].add(contrib)
        y = jax.lax.psum(y, "model")            # merge expert groups
        aux = jax.lax.pmean(aux, tuple(dp))     # identical across model ranks
        return y, aux

    xf = x.reshape(T, D)
    tok_spec = P(dp, None)
    y, aux = jax.shard_map(
        block, mesh=mesh,
        in_specs=(tok_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(tok_spec, P()),
    )(xf, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y.reshape(B, S, D), aux


def _moe_apply_global(p: dict, cfg: ArchConfig, x: jax.Array):
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                    # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balancing aux (Switch): E * Σ_e f_e · p_e
    dispatch_frac = jnp.sum(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0) / T
    prob_frac = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(dispatch_frac * prob_frac)

    C = capacity(T, cfg)
    e_flat = top_i.reshape(-1)                                # [T*k]
    w_flat = top_p.reshape(-1)
    order = jnp.argsort(e_flat)
    e_sorted = e_flat[order]
    tok_sorted = (order // k).astype(jnp.int32)
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    slot = (jnp.arange(T * k, dtype=jnp.int32) - first).astype(jnp.int32)
    keep = slot < C
    dest = jnp.where(keep, e_sorted * C + slot, E * C)

    buf = jnp.zeros((E * C, D), x.dtype).at[dest].set(xf[tok_sorted], mode="drop")
    buf = buf.reshape(E, C, D)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, D)

    back = out[jnp.minimum(dest, E * C - 1)] * keep[:, None]
    contrib = back * w_flat[order][:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok_sorted].add(contrib)
    return y.reshape(B, S, D), aux
