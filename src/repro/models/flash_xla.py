"""Blockwise attention with a FlashAttention-2 style custom VJP, pure XLA.

Without this, the VJP of the blockwise forward (scan over kv blocks) stacks
every block's probability tile as scan residuals — materialising the full
O(S²) score matrix in the backward pass and making every ≥4k-seq training
cell memory-bound (measured: 268 of 378 TB/device/step on hymba train_4k,
EXPERIMENTS.md §Perf iteration H1).  The fix is the standard flash backward:
save only (out, logsumexp) per row, recompute score tiles blockwise for
dq/dk/dv.  Forward bytes stay O(S·d + S²/blk·0), backward recomputes one
tile at a time.

Shapes: qg [B,K,G,Sq,hd] (GQA groups), kt/vt [B,K,Skv,hd]; `window` is a
traced int32 scalar (1<<30 ≈ no window) so hybrid archs with per-layer
windows share one trace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BLK_Q = 512
BLK_KV = 1024
NEG = -1e30


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _mask(qa, ka, window, Skv, masked: bool):
    valid = ka[None, :] < Skv
    if masked:
        valid &= ka[None, :] <= qa[:, None]
        valid &= ka[None, :] > qa[:, None] - window
    return valid


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attention_xla(qg, kt, vt, q_abs, window, masked: bool, scale: float):
    out, _ = _fwd_impl(qg, kt, vt, q_abs, window, masked, scale)
    return out


def _fwd_impl(qg, kt, vt, q_abs, window, masked, scale):
    B, K, G, Sq, hd = qg.shape
    Skv = kt.shape[2]
    bq, bk = min(BLK_Q, Sq), min(BLK_KV, Skv)
    qg_p = _pad_to(qg, 3, bq)
    qa_p = _pad_to(q_abs.astype(jnp.int32), 0, bq)
    kt_p = _pad_to(kt, 2, bk)
    vt_p = _pad_to(vt, 2, bk)
    nq, nk = qg_p.shape[3] // bq, kt_p.shape[2] // bk

    qb = qg_p.reshape(B, K, G, nq, bq, hd).transpose(3, 0, 1, 2, 4, 5)
    qa = qa_p.reshape(nq, bq)
    kb = kt_p.reshape(B, K, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    vb = vt_p.reshape(B, K, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    ka = jnp.arange(nk * bk, dtype=jnp.int32).reshape(nk, bk)

    def q_body(_, qin):
        q, qa_i = qin
        # bf16 tiles through the MXU, f32 softmax/accumulator state — the
        # standard TPU flash mixed-precision recipe; halves tile HBM traffic
        # (EXPERIMENTS.md §Perf iteration H3)
        qf = (q.astype(jnp.float32) * scale).astype(jnp.bfloat16)

        def kv_body(carry, kin):
            m, l, acc = carry
            k, v, ka_i = kin
            s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
            valid = _mask(qa_i, ka_i, window, Skv, masked)
            s = jnp.where(valid[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            vz = jnp.where((ka_i < Skv)[:, None], v.astype(jnp.bfloat16), 0)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p.astype(jnp.bfloat16), vz,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, K, G, bq), NEG, jnp.float32),
                jnp.zeros((B, K, G, bq), jnp.float32),
                jnp.zeros((B, K, G, bq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_body, init, (kb, vb, ka))
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_body, None, (qb, qa))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, K, G, nq * bq, hd)[:, :, :, :Sq]
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, K, G, nq * bq)[:, :, :, :Sq]
    return out.astype(qg.dtype), lse


def _fwd(qg, kt, vt, q_abs, window, masked, scale):
    out, lse = _fwd_impl(qg, kt, vt, q_abs, window, masked, scale)
    return out, (qg, kt, vt, q_abs, window, out, lse)


def _bwd(masked, scale, res, dout):
    qg, kt, vt, q_abs, window, out, lse = res
    B, K, G, Sq, hd = qg.shape
    Skv = kt.shape[2]
    bq, bk = min(BLK_Q, Sq), min(BLK_KV, Skv)

    # row term D = rowsum(dout ⊙ out)
    dO = dout.astype(jnp.float32)
    Drow = jnp.sum(dO * out.astype(jnp.float32), axis=-1)          # [B,K,G,Sq]

    qg_p = _pad_to(qg, 3, bq)
    dO_p = _pad_to(dO, 3, bq)
    lse_p = _pad_to(lse, 3, bq)
    Dr_p = _pad_to(Drow, 3, bq)
    qa_p = _pad_to(q_abs.astype(jnp.int32), 0, bq)
    kt_p = _pad_to(kt, 2, bk)
    vt_p = _pad_to(vt, 2, bk)
    nq, nk = qg_p.shape[3] // bq, kt_p.shape[2] // bk

    qb = qg_p.reshape(B, K, G, nq, bq, hd).transpose(3, 0, 1, 2, 4, 5)
    dOb = dO_p.reshape(B, K, G, nq, bq, hd).transpose(3, 0, 1, 2, 4, 5)
    lseb = lse_p.reshape(B, K, G, nq, bq).transpose(3, 0, 1, 2, 4)
    Drb = Dr_p.reshape(B, K, G, nq, bq).transpose(3, 0, 1, 2, 4)
    qab = qa_p.reshape(nq, bq)
    kb = kt_p.reshape(B, K, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    vb = vt_p.reshape(B, K, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    kab = jnp.arange(nk * bk, dtype=jnp.int32).reshape(nk, bk)

    def kv_body(dq_acc, kin):
        k, v, ka_i = kin
        kf = k.astype(jnp.bfloat16)
        vf = jnp.where((ka_i < Skv)[:, None], v.astype(jnp.bfloat16), 0)

        def q_body(carry, qin):
            dk, dv, dq_acc = carry
            q, dO_i, lse_i, Dr_i, qa_i, qidx = qin
            qf = (q.astype(jnp.float32) * scale).astype(jnp.bfloat16)
            dOb = dO_i.astype(jnp.bfloat16)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf,
                           preferred_element_type=jnp.float32)
            valid = _mask(qa_i, ka_i, window, Skv, masked)
            p = jnp.where(valid[None, None, None],
                          jnp.exp(s - lse_i[..., None]), 0.0)   # [B,K,G,bq,bk]
            dv = dv + jnp.einsum("bkgqs,bkgqd->bksd", p.astype(jnp.bfloat16),
                                 dOb, preferred_element_type=jnp.float32)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", dOb, vf,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - Dr_i[..., None])                      # [B,K,G,bq,bk]
            dsb = ds.astype(jnp.bfloat16)
            dk = dk + jnp.einsum("bkgqs,bkgqd->bksd", dsb, qf,
                                 preferred_element_type=jnp.float32)
            dq_blk = jnp.einsum("bkgqs,bksd->bkgqd", dsb, kf,
                                preferred_element_type=jnp.float32) * scale
            dq_acc = jax.lax.dynamic_update_slice(
                dq_acc,
                (jax.lax.dynamic_slice(
                    dq_acc, (0, 0, 0, qidx * bq, 0), (B, K, G, bq, hd))
                 + dq_blk),
                (0, 0, 0, qidx * bq, 0))
            return (dk, dv, dq_acc), None

        init = (jnp.zeros((B, K, bk, hd), jnp.float32),
                jnp.zeros((B, K, bk, hd), jnp.float32),
                dq_acc)
        (dk, dv, dq_acc), _ = jax.lax.scan(
            q_body, init,
            (qb, dOb, lseb, Drb, qab, jnp.arange(nq)))
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, K, G, nq * bq, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_body, dq0, (kb, vb, kab))
    dq = dq[:, :, :, :Sq].astype(qg.dtype)
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(B, K, nk * bk, hd)[:, :, :Skv].astype(kt.dtype)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(B, K, nk * bk, hd)[:, :, :Skv].astype(vt.dtype)
    return dq, dk, dv, None, None


flash_attention_xla.defvjp(_fwd, _bwd)
