"""Hymba: every layer runs attention heads and Mamba(SSD) heads in
*parallel* on the same normed input, averages the two (per-branch RMS
normed) outputs, then a SwiGLU MLP (arXiv:2411.13676).

Attention is sliding-window except every ``global_layer_every``-th layer
(full attention) — this is what makes the arch sub-quadratic enough for the
long_500k cell (window KV + O(1) SSM state; the few global layers keep a
full cache, sharded over the data axis at 500k).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import mamba as SSD
from repro.models.layers import (EMBED, FFN, HEADS, KV, LAYER, NONE, VOCAB,
                                 ParamBuilder, attention, attention_params,
                                 mlp, mlp_params, rms_norm)


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    b = ParamBuilder(key, dtype)
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    H, hd, n = cfg.n_heads, cfg.hd, cfg.ssm_state
    di = H * hd
    b.add("embed", (V, D), (VOCAB, EMBED), scale=0.02)
    attention_params(b, cfg, "attn/", L)
    # mamba branch
    b.add("ssm/w_x", (L, D, di), (LAYER, EMBED, HEADS))
    b.add("ssm/w_z", (L, D, di), (LAYER, EMBED, HEADS))
    b.add("ssm/w_dt", (L, D, H), (LAYER, EMBED, HEADS))
    b.add("ssm/dt_bias", (L, H), (LAYER, HEADS), zeros=True)
    b.add("ssm/w_B", (L, D, n), (LAYER, EMBED, NONE))
    b.add("ssm/w_C", (L, D, n), (LAYER, EMBED, NONE))
    b.add("ssm/a_log", (L, H), (LAYER, HEADS), zeros=True)
    b.add("ssm/w_out", (L, di, D), (LAYER, HEADS, EMBED))
    b.add("norm_attn", (L, D), (LAYER, EMBED), ones=True)
    b.add("norm_ssm", (L, D), (LAYER, EMBED), ones=True)
    mlp_params(b, cfg, "mlp/", L)
    b.add("ln1", (L, D), (LAYER, EMBED), ones=True)
    b.add("ln2", (L, D), (LAYER, EMBED), ones=True)
    b.add("final_norm", (D,), (EMBED,), ones=True)
    b.add("lm_head", (D, V), (EMBED, VOCAB), scale=0.02)
    return b.params, b.specs


def _ssm_branch(cfg, sp, h, ssm_state, *, chunk):
    B, T, D = h.shape
    H, hd, n = cfg.n_heads, cfg.hd, cfg.ssm_state
    xx = (h @ sp["w_x"]).reshape(B, T, H, hd).astype(jnp.float32)
    z = jax.nn.silu(h @ sp["w_z"])
    dt = jax.nn.softplus((h @ sp["w_dt"]).astype(jnp.float32) + sp["dt_bias"])
    Bm = (h @ sp["w_B"]).astype(jnp.float32)          # [B,T,n], head-shared
    Cm = (h @ sp["w_C"]).astype(jnp.float32)          # (§Perf iteration H5)
    loga = -jnp.exp(sp["a_log"].astype(jnp.float32)) * dt     # [B,T,H]
    if T == 1:
        y, hT = SSD.ssd_step(xx[:, 0], dt[:, 0], Bm[:, 0], Cm[:, 0],
                             loga[:, 0], ssm_state)
        y = y[:, None]
    else:
        y, hT = SSD.ssd_chunked(xx, dt, Bm, Cm, loga, ssm_state, chunk)
    y = y.reshape(B, T, H * hd).astype(h.dtype) * z
    return y @ sp["w_out"], hT


def make_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return dict(
        k=jnp.zeros((L, batch, K, max_seq, hd), dtype),
        v=jnp.zeros((L, batch, K, max_seq, hd), dtype),
        ssm=jnp.zeros((L, batch, cfg.n_heads, cfg.ssm_state, cfg.hd), jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("cfg", "chunk", "remat"))
def forward(cfg: ArchConfig, params: dict, tokens, *, positions=None,
            cache=None, cache_pos=None, chunk: int = 256, remat: bool = True,
            image_embeds=None, audio_feats=None):
    x = params["embed"][tokens]
    B, T, D = x.shape
    if positions is None:
        positions = jnp.arange(T)

    attn_p = {k.removeprefix("attn/"): v for k, v in params.items() if k.startswith("attn/")}
    ssm_p = {k.removeprefix("ssm/"): v for k, v in params.items() if k.startswith("ssm/")}
    mlp_p = {k.removeprefix("mlp/"): v for k, v in params.items() if k.startswith("mlp/")}
    stacks = dict(attn=attn_p, ssm=ssm_p, mlp=mlp_p,
                  norm_attn=params["norm_attn"], norm_ssm=params["norm_ssm"],
                  ln1=params["ln1"], ln2=params["ln2"])
    ssm_state = (cache["ssm"] if cache is not None
                 else jnp.zeros((cfg.n_layers, B, cfg.n_heads, cfg.ssm_state, cfg.hd), jnp.float32))
    kv = {"k": cache["k"], "v": cache["v"]} if cache is not None else None

    def layer_body(x, xs):
        lp, idx, s_l, kv_l = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        is_global = (idx % cfg.global_layer_every) == 0 if cfg.global_layer_every else False
        win = jnp.where(is_global, jnp.int32(1 << 30), jnp.int32(cfg.sliding_window))
        a_out, new_kv = attention(lp["attn"], cfg, h, positions,
                                  cache=kv_l, cache_pos=cache_pos, window=win)
        m_out, new_s = _ssm_branch(cfg, lp["ssm"], h, s_l, chunk=chunk)
        mixed = 0.5 * (rms_norm(a_out, lp["norm_attn"], cfg.norm_eps)
                       + rms_norm(m_out, lp["norm_ssm"], cfg.norm_eps))
        x = x + mixed
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp(lp["mlp"], h)
        return x, (new_s, new_kv)

    body = jax.checkpoint(layer_body) if remat else layer_body
    x, (s_new, kv_new) = jax.lax.scan(
        body, x, (stacks, jnp.arange(cfg.n_layers), ssm_state, kv))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_cache = None
    if cache is not None:
        new_cache = dict(k=kv_new["k"], v=kv_new["v"], ssm=s_new)
    return logits, new_cache, jnp.float32(0.0)
