"""Family dispatch: one API over the model zoo."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import hymba, rwkv6, transformer


def module_for(cfg: ArchConfig):
    if cfg.family == "ssm":
        return rwkv6
    if cfg.family == "hybrid":
        return hymba
    return transformer


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    return module_for(cfg).init_params(cfg, key, dtype)


def forward(cfg: ArchConfig, params, tokens, **kw):
    """Returns (logits, cache_or_state, aux)."""
    mod = module_for(cfg)
    if mod is rwkv6:
        cache = kw.pop("cache", None)
        kw.pop("cache_pos", None)
        return rwkv6.forward(cfg, params, tokens, state=cache, **kw)
    return mod.forward(cfg, params, tokens, **kw)


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    mod = module_for(cfg)
    if mod is rwkv6:
        return rwkv6.make_state(cfg, batch, dtype)
    return mod.make_cache(cfg, batch, max_seq, dtype)
