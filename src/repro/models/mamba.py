"""Selective SSM heads, Mamba-2 / SSD parameterisation (scalar decay per
head per step) — the TPU-friendly chunked form (DESIGN.md §4: Hymba's Mamba
heads are implemented with the SSD scalar-decay variant so the chunk math is
a pair of batched matmuls instead of a per-channel [t,s,d,n] tensor).

Per head (state n, head dim dh):
    h_t = a_t · h_{t-1} + Δ_t · B_tᵀ x_t        a_t = exp(Δ_t · A) ∈ (0,1)
    y_t = C_tᵀ h_t                               h ∈ R^{n×dh}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_step(x, dt, B, C, loga, h):
    """x: [Bt,H,dh]; dt,loga: [Bt,H]; B,C: [Bt,n] (shared across heads —
    Hymba projects one B/C per token); h: [Bt,H,n,dh]."""
    h = h * jnp.exp(loga)[..., None, None] + jnp.einsum(
        "bn,bhd,bh->bhnd", B, x, dt)
    y = jnp.einsum("bn,bhnd->bhd", C, h)
    return y, h


def ssd_chunked(x, dt, B, C, loga, h0, chunk: int):
    """x: [Bt,T,H,dh]; dt,loga: [Bt,T,H]; B,C: [Bt,T,n] (head-shared);
    h0: [Bt,H,n,dh].  Returns (y [Bt,T,H,dh], hT).

    Keeping B/C head-shared (instead of materialising the ×H repeat) cuts
    the scan residual/input traffic by the head count (§Perf iteration H5).
    """
    Bt, T, H, dh = x.shape
    n = B.shape[-1]
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Tp = x.shape[1]
    nc = Tp // chunk
    r4 = lambda a: a.reshape(Bt, nc, chunk, H, dh).transpose(1, 0, 3, 2, 4)
    r3 = lambda a: a.reshape(Bt, nc, chunk, H).transpose(1, 0, 3, 2)
    rn = lambda a: a.reshape(Bt, nc, chunk, n).transpose(1, 0, 2, 3)
    xc, dtc, lac, Bc, Cc = r4(x), r3(dt), r3(loga), rn(B), rn(C)

    @jax.checkpoint
    def body(h, xs):
        # remat: keep the scan VJP from stacking intra-chunk tensors
        # (EXPERIMENTS.md §Perf iteration H2)
        xx, dd, la, BB, CC = xs                      # xx [Bt,H,Lc,dh]; BB/CC [Bt,Lc,n]
        cum = jnp.cumsum(la, axis=2)                 # inclusive, ≤ 0 cumulative
        # inter-chunk: y_t += C_t · exp(cum_t) h_0
        y = jnp.einsum("btn,bht,bhnd->bhtd", CC, jnp.exp(cum), h)
        # intra-chunk: G[t,s] = C_t·B_s (head-shared), decay per head
        G = jnp.einsum("btn,bsn->bts", CC, BB)
        diff = cum[:, :, :, None] - cum[:, :, None, :]
        tri = jnp.tril(jnp.ones((xx.shape[2], xx.shape[2]), bool))
        Dm = jnp.where(tri[None, None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        M = G[:, None] * Dm * dd[:, :, None, :]
        y = y + jnp.einsum("bhts,bhsd->bhtd", M, xx)
        # carry to chunk end
        dec_end = jnp.exp(cum[:, :, -1:] - cum)      # [Bt,H,Lc]
        h = h * jnp.exp(cum[:, :, -1])[..., None, None] + jnp.einsum(
            "bsn,bhsd,bhs->bhnd", BB, xx, dd * dec_end)
        return h, y

    hT, ys = jax.lax.scan(body, h0, (xc, dtc, lac, Bc, Cc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(Bt, Tp, H, dh)
    return y[:, :T], hT
