"""RWKV-6 "Finch" — attention-free time-mix with data-dependent per-channel
decay (arXiv:2404.05892), TPU-adapted.

WKV recurrence per head (k-dim i, v-dim j):
    y_t[j] = Σ_i r_t[i] · (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])
    S_t    = diag(w_t) S_{t-1} + k_tᵀ v_t,   w_t = exp(-exp(w0 + lora(x_t)))

Parallel form: chunked scan.  Within a chunk of length Lc the pairwise decay
factors exp(cum[t-1]-cum[s]) (s < t) are all ≤ 1 (log-decay is negative and
cumulative sums decrease), so the [t, s, i] tensor is numerically safe in
f32 without renormalisation — the standard GLA/RWKV chunking trick.  The
chunk loop is a ``lax.scan`` carrying the [B, H, hd, hd] state, giving O(T)
work and an HLO whose size is independent of sequence length (critical for
the 500k-token cell).

Decode: single-step state update (the long_500k serve path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (EMBED, FFN, HEADS, LAYER, NONE, VOCAB,
                                 ParamBuilder, rms_norm)

LORA_R = 64


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    b = ParamBuilder(key, dtype)
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    H, hd = cfg.n_heads, cfg.hd
    assert H * hd == D, "rwkv6 uses d_model = heads * head_dim"
    b.add("embed", (V, D), (VOCAB, EMBED), scale=0.02)
    for nm in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
        b.add(f"tm/{nm}", (L, D), (LAYER, EMBED), zeros=True)
    for nm in ("wr", "wk", "wv", "wg"):
        b.add(f"tm/{nm}", (L, D, D), (LAYER, EMBED, HEADS))
    b.add("tm/wo", (L, D, D), (LAYER, HEADS, EMBED))
    b.add("tm/w0", (L, D), (LAYER, EMBED), zeros=True)
    b.add("tm/wa", (L, D, LORA_R), (LAYER, EMBED, NONE))
    b.add("tm/wb", (L, LORA_R, D), (LAYER, NONE, EMBED))
    b.add("tm/u", (L, H, hd), (LAYER, HEADS, NONE), zeros=True)
    b.add("tm/ln_out", (L, D), (LAYER, EMBED), ones=True)
    b.add("cm/mu", (L, D), (LAYER, EMBED), zeros=True)
    b.add("cm/mu_r", (L, D), (LAYER, EMBED), zeros=True)
    b.add("cm/w_in", (L, D, F), (LAYER, EMBED, FFN))
    b.add("cm/w_out", (L, F, D), (LAYER, FFN, EMBED))
    b.add("cm/w_r", (L, D, D), (LAYER, EMBED, HEADS))
    b.add("ln1", (L, D), (LAYER, EMBED), ones=True)
    b.add("ln2", (L, D), (LAYER, EMBED), ones=True)
    b.add("final_norm", (D,), (EMBED,), ones=True)
    b.add("lm_head", (D, V), (EMBED, VOCAB), scale=0.02)
    return b.params, b.specs


def _shift(x, prev):
    """Token shift: x_{t-1} with ``prev`` filling t=0.  x: [B,T,D]."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_chunked(r, k, v, logw, u, s0, chunk: int):
    """r,k,v: [B,T,H,hd]; logw: [B,T,H,hd] (≤0); u: [H,hd];
    s0: [B,H,hd,hd]. Returns (y [B,T,H,hd], sT)."""
    B, T, Hh, hd = r.shape
    pad = (-T) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = r.shape[1]
    nc = Tp // chunk
    resh = lambda a: a.reshape(B, nc, chunk, Hh, hd).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(logw)  # [nc,B,H,Lc,hd]

    @jax.checkpoint
    def body(s, xs):
        # remat: without it the scan VJP stacks every intra-chunk tensor
        # ([nc, B, H, Lc, hd] residuals) — measured as the dominant HBM term
        # on the train_4k cells (EXPERIMENTS.md §Perf iteration H2)
        rr, kk, vv, lw = xs                                  # [B,H,Lc,hd]
        cum = jnp.cumsum(lw, axis=2)                         # inclusive
        ce = cum - lw                                        # exclusive
        # inter-chunk: y_inter[t] = (r_t ⊙ exp(ce_t)) @ S_0
        rdec = rr * jnp.exp(ce)
        y = jnp.einsum("bhti,bhij->bhtj", rdec, s)
        # intra-chunk: A[t,s,i] = exp(ce[t,i] - cum[s,i]) for s<t
        diff = ce[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,H,t,s,i]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)[None, None, :, :, None]
        A = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        M = jnp.einsum("bhti,bhtsi,bhsi->bhts", rr, A, kk)
        y = y + jnp.einsum("bhts,bhsj->bhtj", M, vv)
        # current-token bonus
        y = y + jnp.einsum("bhti,hi,bhti,bhtj->bhtj", rr, u, kk, vv)
        # state to chunk end
        dec_end = jnp.exp(cum[:, :, -1:, :] - cum)           # [B,H,Lc,hd]
        s = s * jnp.exp(cum[:, :, -1, :])[..., None] + jnp.einsum(
            "bhti,bhtj->bhij", kk * dec_end, vv)
        return s, y

    sT, ys = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, Tp, Hh, hd)
    return y[:, :T], sT


def _wkv_step(r, k, v, logw, u, s):
    """Single decode step. r,k,v,logw: [B,H,hd]; s: [B,H,hd,hd]."""
    y = jnp.einsum("bhi,bhij->bhj", r, s) + jnp.einsum(
        "bhi,hi,bhi,bhj->bhj", r, u, k, v)
    s = s * jnp.exp(logw)[..., None] + jnp.einsum("bhi,bhj->bhij", k, v)
    return y, s


def _time_mix(cfg, lp, x, prev_tok, s0, *, chunk):
    B, T, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    xs = _shift(x, prev_tok)
    mix = lambda mu: x + (xs - x) * mu
    xf32 = lambda a: a.astype(jnp.float32)
    r = (mix(lp["mu_r"]) @ lp["wr"]).reshape(B, T, H, hd)
    k = (mix(lp["mu_k"]) @ lp["wk"]).reshape(B, T, H, hd)
    v = (mix(lp["mu_v"]) @ lp["wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(mix(lp["mu_g"]) @ lp["wg"])
    lw = lp["w0"] + jnp.tanh(mix(lp["mu_w"]) @ lp["wa"]) @ lp["wb"]
    logw = -jnp.exp(jnp.clip(xf32(lw), -8.0, 4.0)).reshape(B, T, H, hd)
    if T == 1:  # decode fast path: plain state update, no chunk machinery
        y, sT = _wkv_step(xf32(r[:, 0]), xf32(k[:, 0]), xf32(v[:, 0]),
                          logw[:, 0], xf32(lp["u"]), s0)
        y = y[:, None]
    else:
        y, sT = _wkv_chunked(xf32(r), xf32(k), xf32(v), logw,
                             xf32(lp["u"]), s0, chunk)
    y = y.astype(x.dtype).reshape(B, T, D)
    y = rms_norm(y, lp["ln_out"], cfg.norm_eps) * g
    return y @ lp["wo"], x[:, -1, :], sT


def _channel_mix(cfg, lp, x, prev_tok):
    xs = _shift(x, prev_tok)
    xk = x + (xs - x) * lp["mu"]
    xr = x + (xs - x) * lp["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ lp["w_in"]))
    return jax.nn.sigmoid(xr @ lp["w_r"]) * (kk @ lp["w_out"]), x[:, -1, :]


def make_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    L, H, hd, D = cfg.n_layers, cfg.n_heads, cfg.hd, cfg.d_model
    return dict(
        s=jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        tm_prev=jnp.zeros((L, batch, D), dtype),
        cm_prev=jnp.zeros((L, batch, D), dtype),
    )


@functools.partial(jax.jit, static_argnames=("cfg", "chunk", "remat"))
def forward(cfg: ArchConfig, params: dict, tokens, *, state=None,
            chunk: int = 256, remat: bool = True, positions=None,
            image_embeds=None, audio_feats=None, cache=None, cache_pos=None):
    """Returns (logits, new_state, aux=0). ``state`` enables continuation
    (decode uses T=1)."""
    x = params["embed"][tokens]
    B, T, D = x.shape
    if state is None:
        state = make_state(cfg, B, x.dtype)

    tm = {k.removeprefix("tm/"): v for k, v in params.items() if k.startswith("tm/")}
    cm = {k.removeprefix("cm/"): v for k, v in params.items() if k.startswith("cm/")}
    stacks = dict(tm=tm, cm=cm, ln1=params["ln1"], ln2=params["ln2"])

    def layer_body(x, xs):
        lp, s_l, tm_prev, cm_prev = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        att, tm_last, sT = _time_mix(cfg, lp["tm"], h, tm_prev, s_l, chunk=chunk)
        x = x + att
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        ff, cm_last = _channel_mix(cfg, lp["cm"], h, cm_prev)
        x = x + ff
        return x, (sT, tm_last, cm_last)

    body = jax.checkpoint(layer_body) if remat else layer_body
    x, (s_new, tm_new, cm_new) = jax.lax.scan(
        body, x, (stacks, state["s"], state["tm_prev"], state["cm_prev"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_state = dict(s=s_new, tm_prev=tm_new, cm_prev=cm_new)
    return logits, new_state, jnp.float32(0.0)
