"""Shared neural layers (pure JAX, param pytrees + logical sharding specs).

Params are plain dicts of jnp arrays.  Every creator returns
``(params, specs)`` with identical tree structure; a spec is a tuple of
*logical* axis names resolved by ``distributed/sharding.py`` onto the mesh
("model" axis for TP/EP, None for replicated).  No flax — keeps lowering
fully transparent for the dry-run and roofline parsing.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

# logical axis vocabulary
EMBED = "embed"        # d_model                   -> replicated
VOCAB = "vocab"        # vocabulary                -> model
HEADS = "heads"        # attention heads           -> model
KV = "kv"              # kv heads                  -> model (grouped)
FFN = "ffn"            # mlp hidden                -> model
EXPERT = "expert"      # MoE experts               -> model (EP)
LAYER = "layer"        # stacked scan axis         -> replicated
NONE = None


def uniform(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


class ParamBuilder:
    """Collects (name -> array, spec) pairs with a split PRNG stream."""

    def __init__(self, key, dtype=jnp.float32):
        self.key = key
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}

    def add(self, name: str, shape, spec, *, scale=None, zeros=False, ones=False):
        self.key, sub = jax.random.split(self.key)
        if ones:
            arr = jnp.ones(shape, self.dtype)
        elif zeros:
            arr = jnp.zeros(shape, self.dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            s = scale if scale is not None else fan_in ** -0.5
            arr = uniform(sub, shape, s, self.dtype)
        self.params[name] = arr
        self.specs[name] = spec
        return arr


def rms_norm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta):
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / bias / sliding window / cross)
# --------------------------------------------------------------------------
def attention_params(b: ParamBuilder, cfg: ArchConfig, prefix: str, layers: int):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    L = layers
    b.add(f"{prefix}wq", (L, D, H * hd), (LAYER, EMBED, HEADS))
    b.add(f"{prefix}wk", (L, D, K * hd), (LAYER, EMBED, KV))
    b.add(f"{prefix}wv", (L, D, K * hd), (LAYER, EMBED, KV))
    b.add(f"{prefix}wo", (L, H * hd, D), (LAYER, HEADS, EMBED))
    if cfg.qkv_bias:
        b.add(f"{prefix}bq", (L, H * hd), (LAYER, HEADS), zeros=True)
        b.add(f"{prefix}bk", (L, K * hd), (LAYER, KV), zeros=True)
        b.add(f"{prefix}bv", (L, K * hd), (LAYER, KV), zeros=True)
    if cfg.qk_norm:
        b.add(f"{prefix}q_norm", (L, hd), (LAYER, NONE), ones=True)
        b.add(f"{prefix}k_norm", (L, hd), (LAYER, NONE), ones=True)


def attention(
    p: dict, cfg: ArchConfig, x, positions, *,
    kv_x=None,                 # cross-attention source (defaults to x)
    cache=None,                # dict(k,v) [B, K, S_max, hd] + write position
    cache_pos=None,
    causal=True,
    window=None,
    use_rope=True,
):
    """Returns (out [B,S,D], new_cache)."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, src.shape[1], K, hd)
    v = v.reshape(B, src.shape[1], K, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # decode/prefill-into-cache: write new kv at cache_pos, attend over cache
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
            (0, 0, cache_pos, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
            (0, 0, cache_pos, 0))
        new_cache = dict(k=ck, v=cv)
        kt, vt = ck, cv
        q_abs = cache_pos + jnp.arange(S)
    else:
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        q_abs = positions if positions is not None else jnp.arange(S)
    s_kv = kt.shape[2]

    qt = q.transpose(0, 2, 1, 3)                              # [B, H, S, hd]
    group = H // K
    qg = qt.reshape(B, K, group, S, hd)
    masked = kv_x is None and (causal or cache is not None)
    if S * s_kv > _BLOCKWISE_THRESHOLD:
        from repro.models.flash_xla import flash_attention_xla
        win_arr = jnp.int32(1 << 30) if window is None else jnp.asarray(window, jnp.int32)
        out = flash_attention_xla(
            qg, kt, vt, jnp.asarray(q_abs, jnp.int32), win_arr,
            masked, hd ** -0.5).astype(jnp.float32)
    else:
        logits = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                            kt.astype(jnp.float32)) * (hd ** -0.5)
        if masked:
            kv_abs = jnp.arange(s_kv)
            mask = kv_abs[None, :] <= q_abs[:, None]
            if window is not None:
                mask = mask & (kv_abs[None, :] > q_abs[:, None] - window)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vt.astype(jnp.float32))
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    out = jnp.einsum("bsh,hd->bsd", out.astype(x.dtype), p["wo"])
    return out, new_cache


# past this many logit elements per (B,K,G) the O(S·S_kv) score tensor no
# longer fits HBM — switch to the blockwise online-softmax formulation
_BLOCKWISE_THRESHOLD = 2048 * 2048
_BLK_Q = 512
_BLK_KV = 1024


def _blockwise_attention(qg, kt, vt, q_abs, *, masked, window):
    """Memory-bounded attention in pure XLA (flash-style online softmax,
    scan over q blocks × kv blocks).  This is the lowering-anywhere twin of
    kernels/flash_attention.py — the Pallas kernel is the TPU fast path, this
    is what the dry-run and big-seq training lower (DESIGN.md §3).

    qg: [B,K,G,Sq,hd]; kt/vt: [B,K,Skv,hd]; q_abs: int32[Sq]."""
    B, K, G, Sq, hd = qg.shape
    Skv = kt.shape[2]
    bq = min(_BLK_Q, Sq)
    bk = min(_BLK_KV, Skv)
    nq, nk = -(-Sq // bq), -(-Skv // bk)
    pad_kv = nk * bk - Skv
    pad_q = nq * bq - Sq
    if pad_q:
        qg = jnp.pad(qg, ((0, 0),) * 3 + ((0, pad_q), (0, 0)))
        q_abs = jnp.pad(q_abs, (0, pad_q))
    if pad_kv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    scale = hd ** -0.5
    kv_abs = jnp.arange(nk * bk)

    q_blocks = qg.reshape(B, K, G, nq, bq, hd).transpose(3, 0, 1, 2, 4, 5)
    qa_blocks = q_abs.reshape(nq, bq)
    k_blocks = kt.reshape(B, K, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    v_blocks = vt.reshape(B, K, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    ka_blocks = kv_abs.reshape(nk, bk)

    def q_body(_, q_in):
        qb, qa = q_in                                   # [B,K,G,bq,hd], [bq]
        qb = qb.astype(jnp.float32) * scale

        def kv_body(carry, kv_in):
            m, l, acc = carry
            kb, vb, ka = kv_in
            s = jnp.einsum("bkgqd,bksd->bkgqs", qb, kb.astype(jnp.float32))
            valid = ka[None, :] < Skv
            if masked:
                valid &= ka[None, :] <= qa[:, None]
                if window is not None:
                    valid &= ka[None, :] > qa[:, None] - window
            s = jnp.where(valid[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (
            jnp.full((B, K, G, bq), -1e30, jnp.float32),
            jnp.zeros((B, K, G, bq), jnp.float32),
            jnp.zeros((B, K, G, bq, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_body, init, (k_blocks, v_blocks, ka_blocks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_body, None, (q_blocks, qa_blocks))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, K, G, -1, hd)
    return out[:, :, :, :Sq]


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------
def mlp_params(b: ParamBuilder, cfg: ArchConfig, prefix: str, layers: int):
    D, F, L = cfg.d_model, cfg.d_ff, layers
    b.add(f"{prefix}w_gate", (L, D, F), (LAYER, EMBED, FFN))
    b.add(f"{prefix}w_up", (L, D, F), (LAYER, EMBED, FFN))
    b.add(f"{prefix}w_down", (L, F, D), (LAYER, FFN, EMBED))


def mlp(p: dict, x, prefix=""):
    g = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p[f"{prefix}w_down"])


def take_layer(params: dict, i, prefix: str = ""):
    """Slice layer i out of every stacked [L, ...] param with the prefix."""
    out = {}
    for k, v in params.items():
        if prefix and not k.startswith(prefix):
            continue
        out[k.removeprefix(prefix)] = v[i]
    return out
