"""Assigned input shapes and (arch × shape) applicability rules."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SHAPE_IDS = list(SHAPES)


def cell_status(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason).  Skip rules per the assignment:
    * long_500k needs sub-quadratic attention — skipped for pure
      full-attention archs (dense/moe/vlm), run for ssm/hybrid;
    * encoder-only archs have no decode step — decode shapes skipped."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention: 500k context skipped (DESIGN.md §4)"
    return True, ""


def microbatches_for(cfg: ArchConfig, shape: ShapeSpec) -> int:
    """Gradient-accumulation factor for training cells: bounds activation +
    MoE dispatch-buffer memory per device (DESIGN.md §3)."""
    if shape.kind != "train":
        return 1
    tokens = shape.seq_len * shape.global_batch
    if cfg.n_experts:
        return max(1, tokens // (128 * 1024))     # ≤128k tokens per microbatch
    if cfg.d_model >= 8192:
        return max(1, shape.global_batch // 32)   # big dense: 32-seq microbatch
    return max(1, shape.global_batch // 64)
