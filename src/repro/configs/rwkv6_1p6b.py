from repro.configs.base import ArchConfig

# RWKV-6 "Finch" 1.6B: 24L, d_model 2048, attention-free (WKV state),
# d_ff 7168, vocab 65536, data-dependent decay.
CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # WKV heads (head_dim 64)
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65_536,
    attn_free=True,
    source="arXiv:2404.05892 (unverified)",
)
