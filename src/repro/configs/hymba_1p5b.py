from repro.configs.base import ArchConfig

# Hymba-1.5B: 32L, d_model 1600, 25H (GQA kv=5), d_ff 5504, vocab 32001,
# parallel attention + Mamba heads in every layer; sliding-window attention
# with a full-attention layer every 8 (global_layer_every).
CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32_001,
    ssm_state=16,
    sliding_window=1024,
    global_layer_every=8,
    source="arXiv:2411.13676",
)
