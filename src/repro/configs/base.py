"""Architecture config schema + registry (``--arch <id>``)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                   # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None      # hybrid archs
    global_layer_every: int = 0               # 0 = none; else every k-th full attn
    # VLM
    cross_attn_every: int = 0                 # insert 1 cross-attn per k self layers
    vision_embed_dim: int = 0
    vision_tokens: int = 0
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    # structure
    encoder_only: bool = False
    attn_free: bool = False
    audio_feat_dim: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # source provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / hybrid with windowed attn)."""
        return self.attn_free or (self.sliding_window is not None)

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.hd
        attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
        if self.n_experts:
            ffn = self.n_experts * 3 * D * F
        else:
            ffn = 3 * D * F
        per_layer = attn + ffn + 2 * D
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            per_layer = 5 * D * D + 3 * D * F  # rwkv time-mix + channel-mix
        return L * per_layer + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.param_count()
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.hd
        attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
        ffn = self.top_k * 3 * D * F
        emb = V * D * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * D) + emb

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            # VLM needs at least one full (self×k + cross) group
            n_layers=(self.cross_attn_every + 1) if self.cross_attn_every
            else min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            vision_embed_dim=32 if self.vision_embed_dim else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            sliding_window=32 if self.sliding_window else None,
            audio_feat_dim=24 if self.audio_feat_dim else 0,
            cross_attn_every=self.cross_attn_every,
        )


ARCH_IDS = [
    "moonshot-v1-16b-a3b",
    "phi3.5-moe-42b-a6.6b",
    "qwen3-32b",
    "mistral-large-123b",
    "qwen2.5-3b",
    "command-r-plus-104b",
    "llama-3.2-vision-90b",
    "rwkv6-1.6b",
    "hymba-1.5b",
    "hubert-xlarge",
]

_MOD = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a6_6b",
    "qwen3-32b": "qwen3_32b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen2.5-3b": "qwen25_3b",
    "command-r-plus-104b": "command_r_plus_104b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "hymba-1.5b": "hymba_1p5b",
    "hubert-xlarge": "hubert_xlarge",
}


def get_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.CONFIG
