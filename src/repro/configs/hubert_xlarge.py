from repro.configs.base import ArchConfig

# HuBERT X-Large: 48L encoder-only, d_model 1280, 16H, d_ff 5120, vocab 504
# (cluster targets).  Audio frontend (conv feature extractor) is a STUB per
# the assignment: input_specs() provides precomputed frame embeddings.
CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    encoder_only=True,
    audio_feat_dim=512,
    source="arXiv:2106.07447 (unverified)",
)
