from repro.configs.base import ArchConfig

# Llama-3.2-Vision-90B backbone: 100 layers total = 80 self-attn + 20
# cross-attn (1 per 4 self layers), d_model 8192, 64H (GQA kv=8), d_ff 28672,
# vocab 128256.  Vision frontend is a STUB per the assignment: input_specs()
# provides precomputed patch embeddings [B, vision_tokens, vision_embed_dim].
CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab=128_256,
    head_dim=128,
    cross_attn_every=4,          # 1 cross layer per 4 self layers
    vision_embed_dim=1280,
    vision_tokens=1601,          # one tile of 1600 patches + CLS
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-90B-Vision (unverified)",
)
