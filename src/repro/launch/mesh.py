"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (the dry-run must set XLA_FLAGS before
the first jax call)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod (v5e); multi-pod adds a leading 2-pod axis used
    only for data parallelism + hierarchical gradient reduction."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))
