"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs the real train_step (jit, sharded over whatever devices exist) under
the fault-tolerant loop; --inject-fail-at N simulates a node failure.
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_arch
from repro.launch.mesh import make_host_mesh
from repro.train import data as DATA
from repro.train import fault as FAULT
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--inject-fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = OPT.AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 5))
    dcfg = DATA.DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed)

    step_fn = TS.make_train_step(cfg, opt_cfg, microbatches=args.microbatches,
                                 compress_grads=args.compress_grads)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    def init_state():
        state, _ = TS.init_train_state(
            cfg, jax.random.PRNGKey(args.seed),
            compress_grads=args.compress_grads)
        return state

    def batch_fn(step):
        b = DATA.global_batch(dcfg, step, cfg)
        return {k: jnp.asarray(v) for k, v in b.items()}

    injected = {"done": False}

    def injector(step):
        if args.inject_fail_at is not None and step == args.inject_fail_at \
                and not injected["done"]:
            injected["done"] = True
            raise RuntimeError("injected node failure")

    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 10 == 0 or step <= 3:
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e}",
                  flush=True)

    fault_cfg = FAULT.FaultConfig(ckpt_dir=args.ckpt_dir,
                                  ckpt_every=args.ckpt_every)
    state = FAULT.run_loop(
        init_state_fn=init_state, train_step=jit_step, batch_fn=batch_fn,
        total_steps=args.steps, fault=fault_cfg, on_metrics=on_metrics,
        failure_injector=injector)
    print(f"done: {len(losses)} steps, first loss {losses[0]:.4f}, "
          f"last loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
