"""Batched serving driver (continuous batching over a slot pool).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.models import api
from repro.serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")

    params, _ = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.integers(4, 17))
        eng.submit(Request(rid=rid, max_new=args.max_new,
                           prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32)))
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt_len={len(r.prompt)} generated={len(r.out)} "
              f"tokens={r.out[:8]}...")
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s)")
    return done


if __name__ == "__main__":
    main()
