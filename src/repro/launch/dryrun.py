import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell for the production meshes and extract roofline inputs.

Per cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes HLO parse

Single-pod (16×16) results feed EXPERIMENTS.md §Roofline; the 2×16×16 pass
proves the "pod" axis shards.  No arrays are ever materialised —
inputs are ShapeDtypeStructs and ``AOT lower/compile`` never allocates.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch ID] [--shape ID]
        [--multi-pod] [--out results.json] [--attn-block Q,KV]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import shapes as SHP
from repro.configs.base import ARCH_IDS, get_arch
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.roofline import analysis as RA
from repro.roofline import hlo_parse as HP
from repro.serve import serve_step as SRV
from repro.train import optimizer as OPT
from repro.train import train_step as TS

PARAM_DTYPE = jnp.bfloat16


def abstract_state_and_specs(cfg, *, train: bool):
    """Abstract (never-materialised) params / train state + logical specs."""
    cell = {}

    def fn(key):
        params, specs = api.init_params(cfg, key, PARAM_DTYPE)
        cell["specs"] = specs
        if not train:
            return params
        return dict(params=params, opt=OPT.init_state(params),
                    step=jnp.zeros((), jnp.int32))

    shapes = jax.eval_shape(fn, jax.random.PRNGKey(0))
    return shapes, cell["specs"]


def batch_specs(cfg, shape: SHP.ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    out = dict(
        tokens=jax.ShapeDtypeStruct((B, S), jnp.int32),
        labels=jax.ShapeDtypeStruct((B, S), jnp.int32),
    )
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.vision_embed_dim), PARAM_DTYPE)
    if cfg.family == "audio":
        out["audio_feats"] = jax.ShapeDtypeStruct(
            (B, S, cfg.audio_feat_dim), PARAM_DTYPE)
    return out


def cache_specs(cfg, batch: int, max_seq: int):
    shapes = jax.eval_shape(
        lambda: api.init_decode_state(cfg, batch, max_seq, PARAM_DTYPE))
    return shapes


def input_specs(arch: str, shape_id: str):
    """Public helper: ShapeDtypeStruct stand-ins for every model input of a
    cell (assignment MULTI-POD DRY-RUN step 2)."""
    cfg = get_arch(arch)
    shape = SHP.SHAPES[shape_id]
    if shape.kind == "train":
        state, _ = abstract_state_and_specs(cfg, train=True)
        return dict(state=state, batch=batch_specs(cfg, shape))
    params, _ = abstract_state_and_specs(cfg, train=False)
    cache = cache_specs(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        return dict(params=params,
                    tokens=jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32),
                    cache=cache)
    return dict(params=params,
                token=jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
                cache=cache)


def _extra_kw_specs(cfg, batch):
    kw = {}
    if cfg.family == "vlm":
        kw["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.vision_embed_dim), PARAM_DTYPE)
    if cfg.family == "audio":
        kw["audio_feats"] = jax.ShapeDtypeStruct(
            (batch, 1, cfg.audio_feat_dim), PARAM_DTYPE)
    return kw


def build_cell(arch: str, shape_id: str, mesh):
    """Returns (jitted_fn, example_args(kwargs of ShapeDtypeStruct))."""
    cfg = get_arch(arch)
    shape = SHP.SHAPES[shape_id]
    mode = SH.mode_for(cfg)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        state, specs = abstract_state_and_specs(cfg, train=True)
        state_sh = TS.state_shardings(specs, state, mode, mesh)
        bspec = batch_specs(cfg, shape)
        bsh = {k: NamedSharding(mesh, P(dp, *([None] * (len(v.shape) - 1))))
               for k, v in bspec.items()}
        mb = SHP.microbatches_for(cfg, shape)
        # full-recompute remat: the "dots" policy was measured WORSE here
        # (saved-dot residual traffic > recompute savings, +27 GB live set —
        # §Perf iteration M2, refuted)
        step = TS.make_train_step(cfg, OPT.AdamWConfig(), microbatches=mb)
        fn = jax.jit(
            step,
            in_shardings=(state_sh, bsh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        return fn, (state, bspec), dict(microbatches=mb, mode=mode)

    params, specs = abstract_state_and_specs(cfg, train=False)
    p_sh = SH.param_shardings(specs, params, mode, mesh)
    cache = cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_ps = SH.cache_pspecs(cfg, mesh, shape.global_batch)
    cache_sh = {}
    for k, v in cache.items():
        ps = c_ps.get(k, P())
        cache_sh[k] = NamedSharding(mesh, ps)

    extra = _extra_kw_specs(cfg, shape.global_batch)

    b_ax = dp if shape.global_batch % mesh.shape["data"] == 0 else None
    extra_sh = {
        k: NamedSharding(mesh, P(b_ax, *([None] * (len(v.shape) - 1))))
        for k, v in extra.items()
    }

    if shape.kind == "prefill":
        tok_sh = NamedSharding(mesh, P(b_ax, None))
        if cfg.family == "vlm":
            extra["image_embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.vision_tokens, cfg.vision_embed_dim), PARAM_DTYPE)
        if cfg.family == "audio":
            extra["audio_feats"] = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.audio_feat_dim), PARAM_DTYPE)
        extra_sh = {
            k: NamedSharding(mesh, P(b_ax, *([None] * (len(v.shape) - 1))))
            for k, v in extra.items()
        }
        prefill = SRV.make_prefill(cfg, shape.seq_len)
        fn = jax.jit(
            lambda params, tokens, cache, extra: prefill(params, tokens, cache, **extra),
            in_shardings=(p_sh, tok_sh, cache_sh, extra_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,),
        )
        tokens = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
        return fn, (params, tokens, cache, extra), dict(mode=mode)

    # decode
    tok_sh = NamedSharding(mesh, P(b_ax, None))
    decode = SRV.make_decode(cfg)
    fn = jax.jit(
        lambda params, token, cache, pos, extra: decode(params, token, cache, pos, **extra),
        in_shardings=(p_sh, tok_sh, cache_sh, NamedSharding(mesh, P()), extra_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params, token, cache, pos, extra), dict(mode=mode)


def model_flops(cfg, shape: SHP.ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape_id: str, *, multi_pod: bool, hlo_dir=None):
    cfg = get_arch(arch)
    shape = SHP.SHAPES[shape_id]
    ok, reason = SHP.cell_status(cfg, shape)
    if not ok:
        return dict(arch=arch, shape=shape_id, status="skip", reason=reason)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    from repro.distributed import context as CTX
    try:
        CTX.set_current_mesh(mesh)
        with mesh:
            fn, args, meta = build_cell(arch, shape_id, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            try:
                mem = compiled.memory_analysis()
                mem_info = dict(
                    argument_bytes=getattr(mem, "argument_size_in_bytes", None),
                    output_bytes=getattr(mem, "output_size_in_bytes", None),
                    temp_bytes=getattr(mem, "temp_size_in_bytes", None),
                    code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
                )
            except Exception:
                mem_info = {}
            hlo = compiled.as_text()
            hc = HP.parse_hlo(hlo)
            rl = RA.roofline_from_hlo(hc, chips=chips, model_flops=model_flops(cfg, shape))
            if hlo_dir:
                import pathlib
                pathlib.Path(hlo_dir).mkdir(parents=True, exist_ok=True)
                tag = "mp" if multi_pod else "sp"
                (pathlib.Path(hlo_dir) / f"{arch}__{shape_id}__{tag}.hlo.txt").write_text(hlo)
            return dict(
                arch=arch, shape=shape_id, status="ok",
                multi_pod=multi_pod, chips=chips, mode=meta.get("mode"),
                microbatches=meta.get("microbatches", 1),
                lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                flops=rl.flops, bytes_hbm=rl.bytes_hbm,
                bytes_collective=rl.bytes_collective,
                collective_by_kind=hc.coll_bytes,
                collective_ops=hc.coll_ops,
                xla_cost=dict(flops=float(cost.get("flops", -1)),
                              bytes=float(cost.get("bytes accessed", -1))),
                compute_s=rl.compute_s, memory_s=rl.memory_s,
                collective_s=rl.collective_s, dominant=rl.dominant,
                model_flops=rl.model_flops, useful_ratio=rl.useful_ratio,
                roofline_fraction=rl.roofline_fraction,
                mem=mem_info,
            )
    except Exception as e:  # a failed cell is a bug — surface it loudly
        return dict(arch=arch, shape=shape_id, status="error",
                    multi_pod=multi_pod, error=f"{type(e).__name__}: {e}",
                    trace=traceback.format_exc()[-2000:],
                    elapsed_s=round(time.time() - t0, 1))
    finally:
        CTX.set_current_mesh(None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=SHP.SHAPE_IDS + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else SHP.SHAPE_IDS
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    with open(args.out, "a") as f:
        for arch in archs:
            for shape_id in shapes:
                for mp in meshes:
                    res = run_cell(arch, shape_id, multi_pod=mp, hlo_dir=args.hlo_dir)
                    f.write(json.dumps(res) + "\n")
                    f.flush()
                    status = res["status"]
                    msg = res.get("dominant") or res.get("reason") or res.get("error", "")
                    print(f"[{arch} × {shape_id} × {'2pod' if mp else '1pod'}] "
                          f"{status}: {msg}", flush=True)


if __name__ == "__main__":
    main()
