"""MoCHy motif classification tables (paper §II, Fig. 2a).

A hyperedge triad (h_a, h_b, h_c) is classified by the emptiness pattern of
the 7 Venn regions
    (a\\(b∪c), b\\(a∪c), c\\(a∪b), (a∩b)\\c, (a∩c)\\b, (b∩c)\\a, a∩b∩c)
encoded as a 7-bit integer (bit i = region i non-empty).  Of the 128
patterns, those realisable by three distinct, non-empty, *connected*
hyperedges collapse under S3 symmetry into exactly **26 classes** (20
closed + 6 open) — matching MoCHy.  Tables are built once at import with
plain Python and baked into jnp constants:

  CANON[code]    -> canonical (orbit-minimal) code, any of the 128 inputs
  CLASS_ID[code] -> 0..25 for valid canonical codes, -1 otherwise
  CLASS_CLOSED[cls] -> 1 if the class has all three pairs adjacent

Temporal triads (THyMe+) use the *ordered* pattern of the time-sorted triple
instead of the canonical one: TEMPORAL_CLASS_ID maps every valid ordered
code to a dense id.
"""
from __future__ import annotations

from itertools import permutations, product

import numpy as np

_REG = ["a", "b", "c", "ab", "ac", "bc", "abc"]


def _perm_pattern(pat, perm):
    m = dict(zip("abc", perm))
    out = {}
    for k, v in zip(_REG, pat):
        nk = "".join(sorted(m[ch] for ch in k))
        out[nk] = v
    return tuple(out[k] for k in _REG)


def _valid(pat):
    d = dict(zip(_REG, pat))
    for x in "abc":
        if not any(d[k] for k in _REG if x in k):
            return False  # an empty hyperedge
    adj = [d["ab"] or d["abc"], d["ac"] or d["abc"], d["bc"] or d["abc"]]
    if sum(adj) < 2:
        return False  # not a connected triple
    for x, y in [("a", "b"), ("a", "c"), ("b", "c")]:
        z = ({"a", "b", "c"} - {x, y}).pop()
        if (
            d[x] == 0
            and d[y] == 0
            and d["".join(sorted(x + z))] == 0
            and d["".join(sorted(y + z))] == 0
        ):
            return False  # pattern forces two identical hyperedges
    return True


def _code(pat) -> int:
    return sum(b << i for i, b in enumerate(pat))


def _build():
    canon = np.zeros(128, np.int32)
    class_id = np.full(128, -1, np.int32)
    classes: list[int] = []
    closed: list[int] = []
    temporal_id = np.full(128, -1, np.int32)
    n_temporal = 0
    for pat in product([0, 1], repeat=7):
        code = _code(pat)
        cpat = min(_perm_pattern(pat, p) for p in permutations("abc"))
        canon[code] = _code(cpat)
        if _valid(pat):
            if temporal_id[code] < 0:
                temporal_id[code] = n_temporal
                n_temporal += 1
    for pat in product([0, 1], repeat=7):
        code = _code(pat)
        if not _valid(pat):
            continue
        c = canon[code]
        if class_id[c] < 0:
            class_id[c] = len(classes)
            classes.append(c)
            d = dict(zip(_REG, pat))
            # closed iff all three pairs adjacent — class property
            cp = [(c >> 3) & 1 or (c >> 6) & 1, (c >> 4) & 1 or (c >> 6) & 1,
                  (c >> 5) & 1 or (c >> 6) & 1]
            closed.append(1 if sum(cp) == 3 else 0)
        class_id[code] = class_id[c]
    return canon, class_id, np.array(classes, np.int32), np.array(closed, np.int32), temporal_id, n_temporal


CANON, CLASS_ID, CLASS_CODES, CLASS_CLOSED, TEMPORAL_CLASS_ID, NUM_TEMPORAL = _build()
NUM_CLASSES = len(CLASS_CODES)
assert NUM_CLASSES == 26, NUM_CLASSES


def region_code(ca, cb, cc, iab, iac, ibc, iabc):
    """7-bit emptiness code from cardinalities + intersection sizes.

    All args are integer arrays (broadcastable).  Inclusion–exclusion gives
    each exclusive region size; the bit is `size > 0`.
    """
    a_only = ca - iab - iac + iabc
    b_only = cb - iab - ibc + iabc
    c_only = cc - iac - ibc + iabc
    ab = iab - iabc
    ac = iac - iabc
    bc = ibc - iabc
    bits = [a_only, b_only, c_only, ab, ac, bc, iabc]
    code = 0
    for i, b in enumerate(bits):
        code = code + ((b > 0).astype(np.int32) << i)
    return code
