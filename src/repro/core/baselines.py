"""Baselines the paper compares against (Table I / §VI).

* ``mochy_static``     — MoCHy-style full static recount of hyperedge triads
                         (the paper reruns MoCHy per batch; we rerun the same
                         counting engine over the full live region, excluding
                         any incremental machinery).
* ``thyme_static``     — THyMe+-style full static recount of temporal triads.
* ``stathyper_static`` — StatHyper-style full static recount of vertex triads.
* ``mochy_cpu``        — NumPy single-stream recount (stands in for the
                         shared-memory CPU baselines; same algorithm, host
                         execution, no batching/vectorised device parallelism).
* ``Pow2Store``        — Hornet-like dynamic store: power-of-two capacity per
                         list, growth *copies* the whole list into a larger
                         block (the memcpy behaviour Fig. 16 attributes to
                         Hornet), vs ESCHER's copy-free granule blocks +
                         chaining.  Tracks bytes moved for the Fig. 16 ratio.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core import motifs
from repro.core import triads as T
from repro.core import vertex_triads as VT
from repro.core.hypergraph import Hypergraph


def mochy_static(hg: Hypergraph, *, max_deg: int, max_region: int, chunk: int = 1024,
                 backend: str | None = None):
    r, m = T.all_live_region(hg, max_region)
    return T.count_triads(hg, r, m, max_deg=max_deg, chunk=chunk, backend=backend)


def thyme_static(hg: Hypergraph, times, window, *, max_deg: int, max_region: int,
                 chunk: int = 1024, backend: str | None = None):
    r, m = T.all_live_region(hg, max_region)
    return T.count_triads(hg, r, m, max_deg=max_deg, chunk=chunk,
                          temporal=True, times=times, window=window, backend=backend)


def stathyper_static(hg: Hypergraph, v_total, *, max_nb: int, max_region: int,
                     chunk: int = 1024, backend: str | None = None):
    vids = jnp.arange(max_region, dtype=jnp.int32)
    mask = vids < jnp.asarray(v_total, jnp.int32)
    return VT.count_vertex_triads(hg, vids, mask, v_total, max_nb=max_nb, chunk=chunk,
                                  backend=backend)


# --------------------------------------------------------------------------
# Host (NumPy) recount — stands in for the shared-memory CPU baselines
# --------------------------------------------------------------------------
def mochy_cpu(edge_sets: list[set[int]]) -> np.ndarray:
    """Single-stream MoCHy recount: line graph + per-pair candidate scan."""
    n = len(edge_sets)
    # vertex -> edges
    v2e: dict[int, list[int]] = {}
    for i, s in enumerate(edge_sets):
        for v in s:
            v2e.setdefault(v, []).append(i)
    nbrs = [set() for _ in range(n)]
    for ids in v2e.values():
        for i in ids:
            nbrs[i].update(ids)
    for i in range(n):
        nbrs[i].discard(i)
    hist = np.zeros(motifs.NUM_CLASSES, np.int64)
    for a in range(n):
        for b in nbrs[a]:
            if b <= a:
                continue
            sa, sb = edge_sets[a], edge_sets[b]
            iab = len(sa & sb)
            for c in nbrs[a] | nbrs[b]:
                if c == a or c == b:
                    continue
                sc = edge_sets[c]
                iac, ibc = len(sa & sc), len(sb & sc)
                iabc = len(sa & sb & sc)
                code = int(
                    motifs.region_code(
                        np.int32(len(sa)), np.int32(len(sb)), np.int32(len(sc)),
                        np.int32(iab), np.int32(iac), np.int32(ibc), np.int32(iabc),
                    )
                )
                cls = motifs.CLASS_ID[motifs.CANON[code]]
                if cls < 0:
                    continue
                closed = iab > 0 and iac > 0 and ibc > 0
                hist[cls] += 2 if closed else 3
    return hist // 6


# --------------------------------------------------------------------------
# Hornet-like power-of-two store (Fig. 16 contrast)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Pow2Store:
    """Per-list power-of-two blocks; growth reallocates and memcpys."""

    lists: dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    fill: dict[int, int] = dataclasses.field(default_factory=dict)
    bytes_moved: int = 0
    allocs: int = 0

    @staticmethod
    def _cap(n: int) -> int:
        return 1 << max(1, int(np.ceil(np.log2(max(n, 1)))))

    def insert_list(self, key: int, values: np.ndarray) -> None:
        cap = self._cap(len(values))
        buf = np.empty(cap, np.int32)
        buf[: len(values)] = values
        self.lists[key] = buf
        self.fill[key] = len(values)
        self.allocs += 1
        self.bytes_moved += len(values) * 4

    def delete_list(self, key: int) -> None:
        self.lists.pop(key, None)
        self.fill.pop(key, None)

    def append(self, key: int, value: int) -> None:
        buf, n = self.lists[key], self.fill[key]
        if n >= len(buf):  # grow: realloc + copy (the Hornet cost model)
            newbuf = np.empty(len(buf) * 2, np.int32)
            newbuf[:n] = buf[:n]
            self.bytes_moved += n * 4
            self.allocs += 1
            buf = newbuf
            self.lists[key] = buf
        buf[n] = value
        self.fill[key] = n + 1
        self.bytes_moved += 4

    def remove(self, key: int, value: int) -> None:
        buf, n = self.lists[key], self.fill[key]
        idx = np.nonzero(buf[:n] == value)[0]
        if len(idx):
            i = int(idx[0])
            buf[i : n - 1] = buf[i + 1 : n]
            self.bytes_moved += (n - 1 - i) * 4
            self.fill[key] = n - 1


@dataclasses.dataclass
class EscherHostModel:
    """Host cost model of ESCHER's granule blocks + chaining (no realloc
    copies; appends that overflow allocate a chained block instead)."""

    granule: int = 32
    fill: dict[int, int] = dataclasses.field(default_factory=dict)
    caps: dict[int, int] = dataclasses.field(default_factory=dict)
    bytes_moved: int = 0
    allocs: int = 0

    def _blk(self, n: int) -> int:
        g = self.granule
        return ((n + 1 + g - 1) // g) * g

    def insert_list(self, key: int, values: np.ndarray) -> None:
        self.fill[key] = len(values)
        self.caps[key] = self._blk(len(values))
        self.allocs += 1
        self.bytes_moved += len(values) * 4

    def delete_list(self, key: int) -> None:
        self.fill.pop(key, None)
        self.caps.pop(key, None)  # block stays allocated for reuse — no copy

    def append(self, key: int, value: int) -> None:
        n = self.fill[key]
        if n + 1 > self.caps[key] - 1:
            self.caps[key] += self.granule  # chain a block; NO copy of old data
            self.allocs += 1
        self.fill[key] = n + 1
        self.bytes_moved += 4

    def remove(self, key: int, value: int) -> None:
        n = self.fill[key]
        self.bytes_moved += max(n // 2, 1) * 4  # expected shift distance
        self.fill[key] = n - 1
