"""ESCHER flattened store (paper §III-A, Fig. 3).

All incident lists live in one pre-allocated 1-D int32 array ``A``.  Each
list owns a *primary* memory block sized ``ceil((d+1)/granule)*granule`` whose
last slot is metadata: ``END`` (paper's -inf) or an encoded pointer to a
single *overflow* block (insertion Case 2 chaining).  The block manager
(``blockmgr``) indexes blocks; its per-node table also mirrors the chain
(addr0/cap0/addr1/cap1) so reads are two bounded gathers instead of a
pointer walk — the TPU-native adaptation of the paper's linked blocks
(DESIGN.md §2).  The metadata slots in ``A`` are still maintained so the
on-device layout matches the paper's Fig. 3 exactly.

One ``EscherStore`` implements one mapping (h2v, v2h or h2h) — the paper's
"single schema" (§III, Table II).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import blockmgr as bm

EMPTY = jnp.iinfo(jnp.int32).max  # unoccupied vertex slot
END = -1                          # metadata: end of list (paper's -inf)

# ``EscherStore.error`` is a sticky *bitmask* (not a boolean): each failure
# mode owns a bit so callers — the elastic layer above all
# (core/elastic.py, stream.run_stream(auto_grow=True)) — can tell a
# growable condition (capacity / rank space, fixable by ``grow_store``)
# from a structural one (a row exceeding the static ``max_card``).
# ``core/stream.py`` extends the mask with scheduler-level bits and
# ``decode_errors`` names them all.
ERR_CAPACITY = 1   # bump allocator ran past ``A``'s tail (grow capacity)
ERR_RANKS = 2      # fresh ranks exhausted the perfect BST (grow a level)
ERR_ROW_FULL = 4   # a list outgrew the static ``max_card`` (not growable)


def encode_ptr(addr):
    """Metadata encoding of a chain pointer (must not collide with ids>=0)."""
    return -(addr + 2)


def decode_ptr(meta):
    return -meta - 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EscherStore:
    A: jax.Array          # int32[capacity] flattened memory
    mgr: bm.BlockManager
    free_ptr: jax.Array   # int32 scalar: bump pointer into the unindexed tail
    n_ranks: jax.Array    # int32 scalar: number of local ids ever activated
    error: jax.Array      # int32 scalar: sticky overflow flag (capacity/slots)
    granule: int = dataclasses.field(metadata=dict(static=True))
    max_card: int = dataclasses.field(metadata=dict(static=True))

    @property
    def capacity(self) -> int:
        return self.A.shape[0]

    @property
    def n_live(self) -> jax.Array:
        return jnp.sum(self.mgr.present)


def block_size(card, granule: int):
    """Paper's block sizing: ceil((d+1)/granule) * granule."""
    card = jnp.asarray(card, jnp.int32)
    return ((card + 1 + granule - 1) // granule) * granule


def init_store(
    lists: jax.Array,      # int32[n, max_card] vertex ids, EMPTY-padded
    cards: jax.Array,      # int32[n]
    *,
    max_edges: int,
    capacity: int,
    granule: int = 32,
) -> EscherStore:
    """Hypergraph initialisation (paper §III-B): fully parallel — block sizes
    via vectorised arithmetic, addresses via prefix sum, tree nodes placed by
    the closed-form Eq. (1) map, vertices scattered in one shot."""
    n, max_card = lists.shape
    assert n <= max_edges
    mgr = bm.build_manager(max_edges)
    sizes = block_size(cards, granule)
    addr = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(sizes, dtype=jnp.int32)])
    starts, total = addr[:-1], addr[-1]

    A = jnp.full(capacity, EMPTY, jnp.int32)
    # scatter vertices: element (j, s) -> starts[j] + s, masked by s < card[j]
    slot = jnp.arange(max_card, dtype=jnp.int32)[None, :]
    pos = starts[:, None] + slot
    valid = slot < cards[:, None]
    pos = jnp.where(valid, pos, capacity)  # OOB drops (XLA scatter semantics)
    A = A.at[pos.reshape(-1)].set(lists.reshape(-1), mode="drop")
    # metadata slot at end of every primary block
    A = A.at[starts + sizes - 1].set(END)

    idx = bm.cbt_index(jnp.arange(n, dtype=jnp.int32), mgr.height)
    mgr = dataclasses.replace(
        mgr,
        addr0=mgr.addr0.at[idx].set(starts),
        cap0=mgr.cap0.at[idx].set(sizes),
        card=mgr.card.at[idx].set(cards.astype(jnp.int32)),
        present=mgr.present.at[idx].set(1),
    )
    return EscherStore(
        A=A,
        mgr=mgr,
        free_ptr=total,
        n_ranks=jnp.int32(n),
        error=jnp.int32(0),
        granule=granule,
        max_card=max_card,
    )


def read_dense(store: EscherStore, ranks: jax.Array) -> jax.Array:
    """Gather the (padded) incident lists of ``ranks`` -> int32[m, max_card].

    Follows the primary block then the overflow chain; non-present ranks and
    pad slots return EMPTY.  Two bounded gathers — no pointer chasing.
    """
    idx = bm.cbt_index(jnp.maximum(ranks, 0), store.mgr.height)
    a0 = store.mgr.addr0[idx]
    c0 = store.mgr.cap0[idx]
    a1 = store.mgr.addr1[idx]
    card = store.mgr.card[idx]
    present = (store.mgr.present[idx] == 1) & (ranks >= 0)

    slot = jnp.arange(store.max_card, dtype=jnp.int32)[None, :]
    u0 = c0[:, None] - 1                       # usable slots in primary block
    in_primary = slot < u0
    pos = jnp.where(in_primary, a0[:, None] + slot, a1[:, None] + (slot - u0))
    ok = present[:, None] & (slot < card[:, None])
    pos = jnp.clip(pos, 0, store.capacity - 1)
    vals = store.A[pos]
    return jnp.where(ok, vals, EMPTY)


def read_sorted(store: EscherStore, ranks: jax.Array) -> jax.Array:
    """Dense read with rows sorted ascending (EMPTY pads to the end) — the
    layout the intersection kernels expect."""
    return jnp.sort(read_dense(store, ranks), axis=1)


def dedupe_sorted(rows: jax.Array) -> jax.Array:
    """Sort rows along the last axis and collapse duplicate values to EMPTY
    (re-sorted so pads sink to the end) — the canonical sorted-set
    normaliser shared by the triad work-list builders (core/triads.py
    candidate rows, core/vertex_triads.py co-occurrence neighbours)."""
    s = jnp.sort(rows, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s[..., :1], bool), s[..., 1:] == s[..., :-1]], axis=-1)
    return jnp.sort(jnp.where(dup, EMPTY, s), axis=-1)
