"""Dynamic triad-count update framework (paper Alg. 3).

For a churn batch (Del, Ins):
  Step 1  mark deletion-affected region = Del ∪ 1-hop ∪ 2-hop (old graph)
  Step 2  count triads inside the affected region (old graph)
  Step 3  apply the batch through ESCHER's vertical ops
  Step 4  mark insertion-affected region (new graph)
  Step 5  count triads inside the *union* region (new graph)
  Step 6  count ← count − count_del + count_ins

Deviation from the paper's lines 4/10 (recorded here deliberately): both
counts run over the union Aff_Del ∪ Aff_Ins, not each side's own region.
With per-side regions an unchanged triad wholly inside Aff_Ins \\ Aff_Del
would be added but never subtracted; over the union every unchanged triad
appears in both counts and telescopes exactly.  Validated against full
recount in tests/test_update.py.

The same driver handles hyperedge-based, temporal (timestamps ride along)
and incident-vertex triads (region built over vertices instead).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import hypergraph as H
from repro.core import triads as T
from repro.core import vertex_triads as VT
from repro.core.hypergraph import Hypergraph, neighbors
from repro.core.store import EMPTY, read_dense


def _dedupe_pad(vals: jax.Array, max_out: int) -> tuple[jax.Array, jax.Array]:
    s = jnp.sort(vals)
    dup = jnp.concatenate([jnp.zeros_like(s[:1], bool), s[1:] == s[:-1]])
    s = jnp.sort(jnp.where(dup, EMPTY, s))[:max_out]
    return jnp.where(s == EMPTY, 0, s), s != EMPTY


def affected_edges(
    hg: Hypergraph, seeds: jax.Array, mask: jax.Array, *, max_deg: int, max_region: int
):
    """Seeds ∪ 1-hop ∪ 2-hop line-graph neighbourhood (Alg. 3 steps 1/4)."""
    seeds = jnp.where(mask, seeds, EMPTY)
    s_safe = jnp.where(mask, seeds, 0)
    nb1 = neighbors(hg, s_safe, max_deg)
    nb1 = jnp.where(mask[:, None], nb1, EMPTY)
    nb1_flat = nb1.reshape(-1)
    nb1_safe = jnp.where(nb1_flat == EMPTY, 0, nb1_flat)
    nb2 = neighbors(hg, nb1_safe, max_deg)
    nb2 = jnp.where((nb1_flat == EMPTY)[:, None], EMPTY, nb2)
    allv = jnp.concatenate([seeds, nb1_flat, nb2.reshape(-1)])
    return _dedupe_pad(allv, max_region)


def affected_vertices(
    hg: Hypergraph, edge_seeds: jax.Array, mask: jax.Array, *, max_nb: int, max_region: int
):
    """Members of changed hyperedges ∪ their co-members (1-hop closure is
    sufficient for vertex-triad classification — DESIGN.md §3)."""
    rows = read_dense(hg.h2v, jnp.where(mask, edge_seeds, 0))
    rows = jnp.where(mask[:, None], rows, EMPTY)
    flat = rows.reshape(-1)
    f_safe = jnp.where(flat == EMPTY, 0, flat)
    conb = VT.vertex_neighbors(hg, f_safe, max_nb)
    conb = jnp.where((flat == EMPTY)[:, None], EMPTY, conb)
    allv = jnp.concatenate([flat, conb.reshape(-1)])
    return _dedupe_pad(allv, max_region)


def _union_region(r1, m1, r2, m2, max_region):
    allv = jnp.concatenate([jnp.where(m1, r1, EMPTY), jnp.where(m2, r2, EMPTY)])
    return _dedupe_pad(allv, max_region)


def churn_step(
    hg: Hypergraph,
    counts: jax.Array,
    del_ranks: jax.Array,
    del_mask: jax.Array,
    ins_lists: jax.Array,
    ins_cards: jax.Array,
    ins_mask: jax.Array,
    *,
    max_deg: int,
    max_region: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,       # by rank (old); updated for Ins
    ins_times: jax.Array | None = None,   # int32[m] timestamps of insertions
    window: int | None = None,
    backend: str | None = None,
    mesh=None,                            # jax.sharding.Mesh | None
):
    """Un-jitted single-batch core (Alg. 3 steps 1–6), reusable inside scans
    (core/stream.py threads it across batches — DESIGN.md §5).  With ``mesh``
    the affected-region pair list shards across the mesh's devices
    (distributed/triads.py — DESIGN.md §3.2); counts are bit-identical.
    Returns (hg', counts', times', new_ranks, (region, region_mask)) — the
    trailing pair is the union affected region the deltas were counted
    over, i.e. exactly the hyperedge ranks whose triad participation may
    have changed this batch.  ``core/stream.py`` folds it into
    ``StreamState.dirty_epoch`` so the query-service cache can invalidate
    per edge (DESIGN.md §7) instead of discarding it."""
    reg_d, md = affected_edges(hg, del_ranks, del_mask, max_deg=max_deg, max_region=max_region)

    hg_new, new_ranks = H.update_batch(hg, del_ranks, del_mask, ins_lists, ins_cards, ins_mask)
    if temporal:
        times = jnp.asarray(times)
        times_new = times.at[jnp.where(ins_mask, new_ranks, 0)].set(
            jnp.where(ins_mask, ins_times, times[jnp.where(ins_mask, new_ranks, 0)])
        )
    else:
        times_new = times

    reg_i, mi = affected_edges(hg_new, new_ranks, ins_mask, max_deg=max_deg, max_region=max_region)
    reg, m = _union_region(reg_d, md, reg_i, mi, max_region)

    kw = dict(max_deg=max_deg, chunk=chunk, temporal=temporal, window=window, backend=backend)
    count = T.count_triads
    if mesh is not None:
        from repro.distributed import triads as DT
        count = functools.partial(DT.count_triads_sharded, mesh=mesh)
    c_del = count(hg, reg, m, times=times, **kw)
    c_ins = count(hg_new, reg, m, times=times_new, **kw)
    return hg_new, counts - c_del + c_ins, times_new, new_ranks, (reg, m)


@functools.partial(
    jax.jit,
    static_argnames=("max_deg", "max_region", "chunk", "temporal", "window",
                     "backend", "mesh"),
)
def update_triad_counts(
    hg: Hypergraph,
    counts: jax.Array,
    del_ranks: jax.Array,
    del_mask: jax.Array,
    ins_lists: jax.Array,
    ins_cards: jax.Array,
    ins_mask: jax.Array,
    *,
    max_deg: int,
    max_region: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,
    ins_times: jax.Array | None = None,
    window: int | None = None,
    backend: str | None = None,
    mesh=None,
):
    """One churn batch for hyperedge-based (or temporal) triads.
    Returns (hg', counts', times')."""
    hg_new, counts_new, times_new, _, _ = churn_step(
        hg, counts, del_ranks, del_mask, ins_lists, ins_cards, ins_mask,
        max_deg=max_deg, max_region=max_region, chunk=chunk,
        temporal=temporal, times=times, ins_times=ins_times,
        window=window, backend=backend, mesh=mesh)
    return hg_new, counts_new, times_new


def _bucket(n: int, lo: int, hi: int) -> int:
    """Smallest power-of-two region size covering n (bounded)."""
    b = lo
    while b < n and b < hi:
        b *= 2
    return min(b, hi)


@functools.partial(
    jax.jit, static_argnames=("max_deg", "max_region", "temporal"))
def _regions_and_update(hg, del_ranks, del_mask, ins_lists, ins_cards,
                        ins_mask, *, max_deg, max_region, temporal=False,
                        times=None, ins_times=None):
    reg_d, md = affected_edges(hg, del_ranks, del_mask, max_deg=max_deg,
                               max_region=max_region)
    hg_new, new_ranks = H.update_batch(hg, del_ranks, del_mask, ins_lists,
                                       ins_cards, ins_mask)
    if temporal:
        times = jnp.asarray(times)
        safe = jnp.where(ins_mask, new_ranks, 0)
        times_new = times.at[safe].set(
            jnp.where(ins_mask, ins_times, times[safe]))
    else:
        times_new = times
    reg_i, mi = affected_edges(hg_new, new_ranks, ins_mask, max_deg=max_deg,
                               max_region=max_region)
    reg, m = _union_region(reg_d, md, reg_i, mi, max_region)
    return hg_new, times_new, reg, m, jnp.sum(m.astype(jnp.int32))


def update_triad_counts_delta(
    hg, counts, del_ranks, del_mask, ins_lists, ins_cards, ins_mask, *,
    max_deg, chunk=1024, temporal=False, times=None, ins_times=None,
    window=None, backend=None,
):
    """Alg. 3 via *containing-triple* deltas (§Perf iteration E2): subtract
    triads containing a deleted edge (old graph), add triads containing an
    inserted edge (new graph).  Each changed triple counted exactly once;
    O(|batch|·deg²) — immune to affected-region saturation.  Validated
    against full recount in tests/test_update.py."""
    kw = dict(max_deg=max_deg, chunk=chunk, temporal=temporal,
              window=window, backend=backend)
    c_del = T.count_triads_containing(hg, del_ranks, del_mask,
                                      times=times, **kw)
    hg_new, new_ranks = H.update_batch(hg, del_ranks, del_mask, ins_lists,
                                       ins_cards, ins_mask)
    if temporal:
        times = jnp.asarray(times)
        safe = jnp.where(ins_mask, new_ranks, 0)
        times = times.at[safe].set(
            jnp.where(ins_mask, ins_times, times[safe]))
    c_ins = T.count_triads_containing(hg_new, new_ranks, ins_mask,
                                      times=times, **kw)
    return hg_new, counts - c_del + c_ins, times


def update_triad_counts_auto(
    hg, counts, del_ranks, del_mask, ins_lists, ins_cards, ins_mask, *,
    max_deg, max_region, chunk=1024, min_region=64, temporal=False,
    times=None, ins_times=None, window=None, backend=None,
):
    """Host-orchestrated Alg. 3 with *bucketed* region specialisation
    (§Perf iteration E1): the affected region's true size is read back and
    counting runs at the smallest power-of-two padded size that covers it,
    so small batches cost O(|affected|·deg) instead of O(max_region·deg).
    One jit specialisation per bucket — a handful across a run."""
    hg_new, times_new, reg, m, n_aff = _regions_and_update(
        hg, del_ranks, del_mask, ins_lists, ins_cards, ins_mask,
        max_deg=max_deg, max_region=max_region, temporal=temporal,
        times=times, ins_times=ins_times)
    R = _bucket(int(n_aff), min_region, max_region)
    kw = dict(max_deg=max_deg, chunk=min(chunk, max(R * 2, 256)),
              temporal=temporal, window=window, backend=backend)
    c_del = T.count_triads(hg, reg[:R], m[:R], times=times, **kw)
    c_ins = T.count_triads(hg_new, reg[:R], m[:R], times=times_new, **kw)
    return hg_new, counts - c_del + c_ins, times_new


def vertex_churn_step(
    hg: Hypergraph,
    counts: jax.Array,       # int32[3]
    v_total: jax.Array | int,
    del_ranks: jax.Array,
    del_mask: jax.Array,
    ins_lists: jax.Array,
    ins_cards: jax.Array,
    ins_mask: jax.Array,
    *,
    max_nb: int,
    max_region: int,
    chunk: int = 1024,
    backend: str | None = None,
    mesh=None,
):
    """Un-jitted single-batch core for incident-vertex triads, reusable
    inside scans (DESIGN.md §5).  With ``mesh`` the affected-region vertex
    pair list shards across the mesh's devices (DESIGN.md §3.2).
    Returns (hg', counts', new_ranks, (region, region_mask)); the trailing
    pair is the union affected *vertex* region — the vertices whose local
    triad participation may have changed (feeds
    ``StreamState.v_dirty_epoch``, DESIGN.md §7)."""
    reg_d, md = affected_vertices(hg, del_ranks, del_mask, max_nb=max_nb, max_region=max_region)
    hg_new, new_ranks = H.update_batch(hg, del_ranks, del_mask, ins_lists, ins_cards, ins_mask)
    reg_i, mi = affected_vertices(hg_new, new_ranks, ins_mask, max_nb=max_nb, max_region=max_region)
    reg, m = _union_region(reg_d, md, reg_i, mi, max_region)

    kw = dict(max_nb=max_nb, chunk=chunk, backend=backend)
    count = VT.count_vertex_triads
    if mesh is not None:
        from repro.distributed import triads as DT
        count = functools.partial(DT.count_vertex_triads_sharded, mesh=mesh)
    c_del = count(hg, reg, m, v_total, **kw)
    c_ins = count(hg_new, reg, m, v_total, **kw)
    return hg_new, counts - c_del + c_ins, new_ranks, (reg, m)


@functools.partial(
    jax.jit,
    static_argnames=("max_nb", "max_region", "chunk", "backend", "mesh")
)
def update_vertex_triad_counts(
    hg: Hypergraph,
    counts: jax.Array,       # int32[3]
    v_total: jax.Array | int,
    del_ranks: jax.Array,
    del_mask: jax.Array,
    ins_lists: jax.Array,
    ins_cards: jax.Array,
    ins_mask: jax.Array,
    *,
    max_nb: int,
    max_region: int,
    chunk: int = 1024,
    backend: str | None = None,
    mesh=None,
):
    """One churn batch for incident-vertex triads. Returns (hg', counts')."""
    hg_new, counts_new, _, _ = vertex_churn_step(
        hg, counts, v_total, del_ranks, del_mask, ins_lists, ins_cards,
        ins_mask, max_nb=max_nb, max_region=max_region, chunk=chunk,
        backend=backend, mesh=mesh)
    return hg_new, counts_new
