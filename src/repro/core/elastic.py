"""Elastic ESCHER store: host-coordinated growth and compaction (DESIGN.md §8).

The base store is fixed-capacity twice over: the flattened array ``A`` is a
bump allocator that never reclaims, and the perfect-BST block manager has a
static rank space of ``2^h - 1``.  Either bound saturating sets a sticky
error bit (``store.ERR_CAPACITY`` / ``store.ERR_RANKS``) and every result
after that point is garbage — "pre-size or die".  This module supplies the
two host-coordinated repairs that turn the store into an open-ended
structure; both preserve every live list and every rank bit-exactly, so all
downstream ids (stream ``rank_of`` maps, cached query keys, ``times`` /
dirty-epoch indices) stay valid:

  * ``grow_store`` / ``grow_hypergraph`` — geometric regrowth: re-allocate
    ``A`` at a larger capacity (block addresses are absolute, so the old
    contents are a prefix copy — no migration), and/or raise the perfect
    BST one or more levels (``blockmgr.grow_manager`` moves every node to
    its new heap index while the in-order *rank* of each node — the paper's
    hyperedge id — is unchanged by construction).

  * ``compact_store`` / ``compact_hypergraph`` — defragmentation: rebuild
    ``A`` so every live list owns a single right-sized primary block
    (insertion Case-2 overflow chains fold back into primaries), and
    reclaim everything else — leaked overflow blocks from horizontal
    regrowth, the oversized blocks of deleted edges, the granule blocks of
    empty lists.  Freed tree nodes keep their ``deleted`` flag — insertion
    Case 1 still reuses their *ids* — but their blocks are stripped to
    zero capacity; reuse then allocates fresh from the compacted tail
    (ops.py's zero-capacity chain path).

``core/stream.py`` drives both from ``run_stream(auto_grow=True)``: a
sticky growable error at a segment boundary rolls the segment back,
compacts and/or grows the checkpoint, and re-runs — bit-identically,
because nothing observable depends on block layout, capacity padding, or
tree height (tests/test_elastic.py, tests/test_elastic_property.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import blockmgr as bm
from repro.core.hypergraph import Hypergraph
from repro.core.store import EMPTY, END, EscherStore, block_size, read_dense


def grow_store(
    store: EscherStore,
    *,
    capacity: int | None = None,
    levels: int = 0,
    register_ranks: bool = False,
) -> EscherStore:
    """Re-allocate ``A`` at ``capacity`` (None = unchanged) and/or grow the
    block manager by ``levels`` tree levels (rank space ×2 per level).

    Contents are preserved bit-exactly: block addresses are absolute so the
    old ``A`` is a prefix of the new one, and ``grow_manager`` migrates
    every node to its new heap index under the same rank.  ``free_ptr``,
    ``n_ranks`` and the sticky ``error`` carry over untouched — growth
    repairs *future* overflow, it cannot launder a store that already
    overflowed (roll back to a pre-error checkpoint instead, as
    ``run_stream(auto_grow=True)`` does).

    ``register_ranks=True`` (the v2h idiom) marks every rank of the grown
    tree present with a zero-capacity primary: vertex ids beyond the old
    universe become valid incident lists whose first block is allocated
    lazily on first insert (ops.py handles ``cap0 == 0`` end to end)."""
    cap_old = store.capacity
    cap = cap_old if capacity is None else int(capacity)
    if cap < cap_old:
        raise ValueError(f"capacity {cap} < current {cap_old}: cannot shrink"
                         " (use compact_store to reclaim the tail)")
    A = store.A if cap == cap_old else jnp.concatenate(
        [store.A, jnp.full(cap - cap_old, EMPTY, jnp.int32)])
    mgr = bm.grow_manager(store.mgr, levels)
    n_ranks = store.n_ranks
    if register_ranks:
        # register only never-used ranks: a deleted rank must stay in the
        # Case-1 free pool (present=0, deleted=1), not come back to life
        # with its stale pre-delete contents
        n_slots = (1 << mgr.height) - 1
        ranks = jnp.arange(n_slots, dtype=jnp.int32)
        idx = bm.cbt_index(ranks, mgr.height)
        fresh = (mgr.present[idx] == 0) & (mgr.deleted[idx] == 0)
        mgr = dataclasses.replace(
            mgr, present=mgr.present.at[idx].max(fresh.astype(jnp.int32)))
        n_ranks = jnp.int32(n_slots)
    return dataclasses.replace(store, A=A, mgr=mgr, n_ranks=n_ranks)


def _live_layout(store: EscherStore):
    """Per-rank layout facts shared by ``compact_store`` and
    ``store_stats`` — one derivation, so the stats-driven compact-vs-double
    policy (stream.py) can never disagree with what compaction actually
    reclaims.  Returns ``(ranks, idx, present, card, keep, sizes)`` where
    ``sizes`` is the right-sized block footprint of each kept list."""
    mgr = store.mgr
    ranks = jnp.arange((1 << mgr.height) - 1, dtype=jnp.int32)
    idx = bm.cbt_index(ranks, mgr.height)
    present = mgr.present[idx] == 1
    card = jnp.where(present, mgr.card[idx], 0)
    keep = present & (card > 0)
    sizes = jnp.where(keep, block_size(card, store.granule), 0)
    return ranks, idx, present, card, keep, sizes


def compact_store(
    store: EscherStore, *, capacity: int | None = None
) -> EscherStore:
    """Defragment: every live non-empty list gets a single right-sized
    primary block (paper sizing, chain folded in), placed by one prefix
    sum in rank order; everything else — chains, dead blocks, empty-list
    blocks — returns to the free tail.  ``capacity`` optionally re-sizes
    ``A`` in the same pass (it must cover the compacted prefix).

    Reads are unchanged bit-for-bit (``read_dense`` row order is the
    stored order, which the rebuild preserves), ranks are untouched, and
    freed tree nodes stay ``deleted`` so insertion Case 1 keeps reusing
    their ids — only their blocks are stripped (zero-capacity, lazily
    re-allocated on reuse)."""
    mgr = store.mgr
    ranks, idx, present, card, keep, sizes = _live_layout(store)
    addr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(sizes, dtype=jnp.int32)])
    starts, total = addr[:-1], int(addr[-1])

    cap = store.capacity if capacity is None else int(capacity)
    if total > cap:
        raise ValueError(
            f"live contents need {total} slots > capacity {cap}")

    rows = read_dense(store, ranks)               # [n_slots, max_card]
    A = jnp.full(cap, EMPTY, jnp.int32)
    slot = jnp.arange(store.max_card, dtype=jnp.int32)[None, :]
    pos = jnp.where(keep[:, None] & (slot < card[:, None]),
                    starts[:, None] + slot, cap)
    A = A.at[pos.reshape(-1)].set(rows.reshape(-1), mode="drop")
    A = A.at[jnp.where(keep, starts + sizes - 1, cap)].set(END, mode="drop")

    mgr = dataclasses.replace(
        mgr,
        addr0=mgr.addr0.at[idx].set(jnp.where(keep, starts, -1)),
        cap0=mgr.cap0.at[idx].set(sizes),
        addr1=mgr.addr1.at[idx].set(-1),
        cap1=mgr.cap1.at[idx].set(0),
    )
    # ``deleted`` / ``avail`` are untouched: the free-id pool survives
    # compaction even though the freed *blocks* do not.
    return dataclasses.replace(
        store, A=A, mgr=mgr, free_ptr=jnp.int32(total))


def grow_hypergraph(
    hg: Hypergraph,
    *,
    h2v_capacity: int | None = None,
    v2h_capacity: int | None = None,
    h2v_levels: int = 0,
    v2h_levels: int = 0,
) -> Hypergraph:
    """Grow either store of the two-way pair.  ``h2v_levels`` widens the
    hyperedge rank space (insertion Case 3 gets more dummy slots to
    activate); ``v2h_levels`` widens the *vertex universe* — the new
    vertex ids come up registered with lazily-allocated incident lists, so
    ``hg.num_vertices`` grows and edges over the new ids insert normally."""
    return Hypergraph(
        h2v=grow_store(hg.h2v, capacity=h2v_capacity, levels=h2v_levels),
        v2h=grow_store(hg.v2h, capacity=v2h_capacity, levels=v2h_levels,
                       register_ranks=v2h_levels > 0),
    )


def compact_hypergraph(hg: Hypergraph) -> Hypergraph:
    return Hypergraph(h2v=compact_store(hg.h2v),
                      v2h=compact_store(hg.v2h))


def store_stats(store: EscherStore) -> dict:
    """Host-side allocator observability: capacity, bump-allocator level,
    minimal (compacted) footprint, live chain count, and the fragmentation
    ratio ``1 - live/used`` that ``run_stream(auto_grow=True)`` uses to
    choose compaction over growth."""
    mgr = store.mgr
    _, idx, present, _, _, sizes = _live_layout(store)
    live = int(jnp.sum(sizes))
    used = int(store.free_ptr)
    return {
        "capacity": store.capacity,
        "used": used,
        "live": live,
        "n_chained": int(jnp.sum((mgr.addr1[idx] >= 0) & present)),
        "n_live_lists": int(jnp.sum(present.astype(jnp.int32))),
        "rank_slots": (1 << mgr.height) - 1,
        "ranks_used": int(store.n_ranks),
        "fragmentation": 0.0 if used == 0 else 1.0 - live / used,
    }
