"""Streaming evolution engine: event log → churn batches → live triad counts.

The paper's setting is a *stream* of hyperedge churn; `update.py` only knows
how to telescope one `(Del, Ins)` batch.  This module supplies the missing
driver (DESIGN.md §5):

  * ``EventLog`` — a fixed-shape ring buffer of timestamped hyperedge events
    (INS carries the member list, DEL carries the *sequence number* of the
    insert event it removes — producers never need to know store ranks);
  * a batch scheduler (``_pop_batch``) that coalesces up to ``batch`` events
    per step and enforces a consistency barrier: a DEL whose INS sits in the
    same batch is deferred to the next batch, so deletes always resolve
    against an edge the store has already materialised;
  * ``run_stream`` — a ``jax.jit``/``lax.scan`` driver threading the Alg. 3
    single-batch cores (``update.churn_step`` / ``update.vertex_churn_step``)
    across batches for all three triad families.  In temporal mode an
    optional sliding retention window ``expiry`` turns aged-out inserts into
    automatic deletions (up to ``batch`` per step; the backlog drains over
    subsequent steps — ``plan_steps`` sizes the scan to finish the drain).

Error handling is sticky throughout: ring overflow on push, malformed
deletes (DEL preceding its INS in the log), slot collisions (an edge
outliving ``capacity`` subsequent events), and the stores' own overflow
flags all fold into ``StreamState.error`` and survive the scan.  The flag
is a *bitmask* — one bit per failure mode (``ERROR_FLAGS``), decoded on
the host by ``decode_errors`` together with the epoch at which each bit
first tripped (``StreamState.error_epoch``), so a failed run reports
*what* went wrong and *at which batch* instead of a bare int32.

``run_stream(auto_grow=True)`` turns the growable subset of those errors
(store capacity, rank space — core/elastic.py, DESIGN.md §8) into
open-ended ingestion: the scan runs in host-checkpointed segments, a
growable error at a segment boundary rolls the segment back, compacts
and/or doubles the checkpointed stores, and re-runs the segment
bit-identically — counts, dirty maps and epochs carry over because growth
preserves every rank and every list verbatim.

Shape discipline: everything is fixed-shape.  ``batch`` bounds the events
popped per step, the same ``batch`` bounds expiry deletions per step, so the
churn core always sees ``2*batch`` deletion slots and ``batch`` insertion
slots — one XLA trace per (batch, mode) regardless of stream content.

Temporal mode inherits the THyMe+ tiebreak contract from triads.py: event
timestamps must be pairwise distinct (triples are time-ordered and ties make
the ordering role-dependent).  ``generators.event_stream`` emits strictly
increasing timestamps for exactly this reason.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elastic as E
from repro.core import update as U
from repro.core.hypergraph import Hypergraph
from repro.core.store import EMPTY, ERR_CAPACITY, ERR_RANKS, ERR_ROW_FULL

INS = 0
DEL = 1
_I32_MIN = jnp.iinfo(jnp.int32).min

# Scheduler-level sticky error bits, disjoint from the store's
# (store.ERR_CAPACITY=1 / ERR_RANKS=2 / ERR_ROW_FULL=4).
ERR_LOG_OVERFLOW = 8      # push_events rejected events (ring full)
ERR_MALFORMED_DEL = 16    # a DEL preceded its INS in the log (dropped)
ERR_SLOT_COLLISION = 32   # an edge outlived ``capacity`` subsequent events

ERROR_FLAGS = (
    (ERR_CAPACITY, "store-capacity-overflow"),
    (ERR_RANKS, "rank-space-exhausted"),
    (ERR_ROW_FULL, "row-exceeds-max-card"),
    (ERR_LOG_OVERFLOW, "event-log-overflow"),
    (ERR_MALFORMED_DEL, "malformed-delete"),
    (ERR_SLOT_COLLISION, "ring-slot-collision"),
)
N_ERR_BITS = len(ERROR_FLAGS)

# the bits ``auto_grow`` can repair by re-sizing the stores; the rest are
# structural (static max_card / log sizing) and stay sticky
GROWABLE_ERRORS = ERR_CAPACITY | ERR_RANKS


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EventLog:
    t: jax.Array      # int32[C] timestamps
    kind: jax.Array   # int32[C] INS | DEL
    lists: jax.Array  # int32[C, max_card] sorted members (INS), EMPTY-padded
    cards: jax.Array  # int32[C]
    ref: jax.Array    # int32[C] DEL: sequence number of the INS it removes
    head: jax.Array   # int32 scalar — next sequence number to consume
    tail: jax.Array   # int32 scalar — next sequence number to produce
    error: jax.Array  # int32 scalar — sticky push overflow / malformed DEL

    @property
    def capacity(self) -> int:
        return self.t.shape[0]

    @property
    def n_pending(self) -> jax.Array:
        return self.tail - self.head


def make_event_log(capacity: int, max_card: int) -> EventLog:
    z = jnp.zeros(capacity, jnp.int32)
    return EventLog(
        t=z, kind=z, lists=jnp.full((capacity, max_card), EMPTY, jnp.int32),
        cards=z, ref=jnp.full(capacity, EMPTY, jnp.int32),
        head=jnp.int32(0), tail=jnp.int32(0), error=jnp.int32(0),
    )


def push_events(log: EventLog, t, kind, lists, cards, ref, mask) -> EventLog:
    """Append masked events at the tail (ring semantics).  Events that would
    overrun ``capacity`` un-consumed slots are rejected and set the sticky
    error flag; accepted events are always a prefix of the masked ones."""
    C = log.capacity
    m = mask.astype(jnp.int32)
    seq = log.tail + jnp.cumsum(m) - m            # per-event sequence number
    accepted = mask & (seq - log.head < C)
    slot = jnp.where(accepted, seq % C, C)        # C = out of bounds -> drop
    new = EventLog(
        t=log.t.at[slot].set(t, mode="drop"),
        kind=log.kind.at[slot].set(kind, mode="drop"),
        lists=log.lists.at[slot].set(lists, mode="drop"),
        cards=log.cards.at[slot].set(cards, mode="drop"),
        ref=log.ref.at[slot].set(ref, mode="drop"),
        head=log.head,
        tail=log.tail + jnp.sum(accepted.astype(jnp.int32)),
        error=log.error
        | jnp.any(mask & ~accepted).astype(jnp.int32) * ERR_LOG_OVERFLOW,
    )
    return new


def log_from_events(events, *, max_card: int, capacity: int | None = None) -> EventLog:
    """Host builder.  ``events`` is a list of
    ``(t, "ins", [v0, v1, ...])`` or ``(t, "del", ref)`` tuples, where
    ``ref`` is the *index in this list* of the insert being removed."""
    n = len(events)
    C = capacity or max(n, 1)
    t = np.zeros(C, np.int32)
    kind = np.zeros(C, np.int32)
    lists = np.full((C, max_card), EMPTY, np.int32)
    cards = np.zeros(C, np.int32)
    ref = np.full(C, EMPTY, np.int32)
    if n > C:
        raise ValueError(f"{n} events exceed log capacity {C}")
    for i, (ti, k, payload) in enumerate(events):
        t[i] = ti
        if k == "ins":
            kind[i] = INS
            e = sorted(payload)
            if len(e) > max_card:
                raise ValueError(
                    f"event {i}: {len(e)} members exceed max_card={max_card}")
            lists[i, : len(e)] = e
            cards[i] = len(e)
        elif k == "del":
            kind[i] = DEL
            ref[i] = int(payload)
        else:
            raise ValueError(f"unknown event kind {k!r}")
    return EventLog(
        t=jnp.asarray(t), kind=jnp.asarray(kind), lists=jnp.asarray(lists),
        cards=jnp.asarray(cards), ref=jnp.asarray(ref),
        head=jnp.int32(0), tail=jnp.int32(n), error=jnp.int32(0),
    )


def _pop_batch(log: EventLog, batch: int):
    """Coalesce up to ``batch`` pending events.  Returns
    ``((t, kind, lists, cards, ref, ok), log')`` with fixed shapes.

    Consistency barrier: a DEL whose INS has not been consumed yet
    (``ref >= head``) either (a) sits earlier in this same batch — the batch
    is truncated right before the DEL, so the next step sees the insert
    already applied — or (b) sits at/after the DEL itself, which means the
    log is malformed (delete precedes its insert); the event is dropped and
    the sticky error set.  Case (a) cannot occur at offset 0, so the
    scheduler always makes progress."""
    C = log.capacity
    offs = jnp.arange(batch, dtype=jnp.int32)
    seq = log.head + offs
    avail = seq < log.tail
    slot = seq % C
    t, kind, ref = log.t[slot], log.kind[slot], log.ref[slot]
    lists, cards = log.lists[slot], log.cards[slot]

    unconsumed = avail & (kind == DEL) & (ref >= log.head) & (ref != EMPTY)
    defer = unconsumed & (ref < seq)        # its INS is earlier in this batch
    malformed = unconsumed & (ref >= seq)   # DEL precedes its INS in the log
    first_defer = jnp.min(jnp.where(defer, offs, batch))
    take = avail & (offs < first_defer)
    ok = take & ~malformed

    log2 = EventLog(
        t=log.t, kind=log.kind, lists=log.lists, cards=log.cards, ref=log.ref,
        head=log.head + jnp.sum(take.astype(jnp.int32)),
        tail=log.tail,
        error=log.error
        | jnp.any(malformed & take).astype(jnp.int32) * ERR_MALFORMED_DEL,
    )
    return (t, kind, lists, cards, ref, ok), log2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamState:
    hg: Hypergraph
    counts: jax.Array   # int32[26 | NUM_TEMPORAL | 3] depending on mode
    times: jax.Array    # int32[n_edge_slots] timestamps by rank
    log: EventLog
    rank_of: jax.Array  # int32[C] log slot -> live store rank, EMPTY if dead
    live_t: jax.Array   # int32[C] log slot -> timestamp of live insert
    t_now: jax.Array    # int32 scalar — stream clock (max event time seen)
    error: jax.Array    # int32 scalar — sticky bitmask (ERROR_FLAGS)
    error_epoch: jax.Array  # int32[N_ERR_BITS] — epoch each bit first
                            # tripped, -1 = never (decode_errors)
    # --- epoch / dirty bookkeeping (query service, DESIGN.md §7) ---------
    # epoch counts applied scheduler steps; the dirty maps record, per
    # hyperedge rank / vertex id, the LAST epoch whose batch may have
    # changed its triad participation (the union affected regions that
    # update.churn_step / vertex_churn_step now return instead of
    # dropping).  A cached per-edge answer from epoch E is valid at a later
    # snapshot iff dirty_epoch[rank] <= E; the slots dirtied by the most
    # recent batch alone are exactly ``dirty_epoch == epoch``
    # (observability: "what did the last batch touch?").
    epoch: jax.Array          # int32 scalar — applied scheduler steps
    dirty_epoch: jax.Array    # int32[n_edge_slots] by hyperedge rank
    v_dirty_epoch: jax.Array  # int32[num_vertices] by vertex id


def make_stream(hg: Hypergraph, log: EventLog, counts, *, times=None) -> StreamState:
    """Initial driver state.  ``counts`` must be the triad histogram of
    ``hg`` as it stands (zeros for an empty hypergraph, or a static count).
    Edges pre-existing in ``hg`` are outside the event log's bookkeeping, so
    they can never be expired or deleted by DEL events — start from an empty
    hypergraph when using the retention window."""
    C = log.capacity
    if times is None:
        times = jnp.zeros(hg.n_edge_slots, jnp.int32)
    return StreamState(
        hg=hg, counts=jnp.asarray(counts), times=jnp.asarray(times), log=log,
        rank_of=jnp.full(C, EMPTY, jnp.int32),
        live_t=jnp.full(C, EMPTY, jnp.int32),
        t_now=jnp.int32(_I32_MIN), error=jnp.int32(0),
        error_epoch=jnp.full(N_ERR_BITS, -1, jnp.int32),
        epoch=jnp.int32(0),
        dirty_epoch=jnp.zeros(hg.n_edge_slots, jnp.int32),
        v_dirty_epoch=jnp.zeros(hg.num_vertices, jnp.int32),
    )


def _dedupe_earliest(slots: jax.Array, ok: jax.Array):
    """Keep only the first occurrence of each slot among ok entries."""
    n = slots.shape[0]
    eq = (slots[:, None] == slots[None, :]) & ok[:, None] & ok[None, :]
    earlier = jnp.tril(jnp.ones((n, n), bool), k=-1)
    dup = jnp.any(eq & earlier, axis=1)
    return ok & ~dup


def _stream_step(
    state: StreamState, *, batch, mode, max_deg, max_nb, max_region, chunk,
    window, expiry, v_total, backend, mesh, track_dirty,
):
    C = state.log.capacity
    head0 = state.log.head
    (t, kind, lists, cards, ref, ok), log = _pop_batch(state.log, batch)
    slot = (head0 + jnp.arange(batch, dtype=jnp.int32)) % C

    ins_ok = ok & (kind == INS)
    del_ok = ok & (kind == DEL)

    t_hi = jnp.max(jnp.where(ok, t, _I32_MIN))
    t_now = jnp.maximum(state.t_now, t_hi)

    # resolve explicit deletes through the slot -> rank map; a DEL of an edge
    # already removed (double delete, or expired earlier) is a silent no-op
    dslot = jnp.where(del_ok, ref % C, 0)
    dranks = state.rank_of[dslot]
    del_ok &= dranks != EMPTY
    del_ok = _dedupe_earliest(dslot, del_ok)

    # retention-window expiry: the oldest ≤ batch live inserts aged past
    # t_now - expiry re-enter as deletions (backlog drains across steps).
    # Slots freed by this batch's explicit deletes are excluded *before*
    # the top-`batch` selection so they cannot consume the expiry quota —
    # plan_steps relies on the full quota going to genuinely-live edges.
    if expiry is not None:
        key = jnp.where(state.live_t == EMPTY, jnp.iinfo(jnp.int32).max,
                        state.live_t)
        key = key.at[jnp.where(del_ok, dslot, C)].set(
            jnp.iinfo(jnp.int32).max, mode="drop")
        order = jnp.argsort(key)[:batch].astype(jnp.int32)
        exp_ok = (key[order] <= t_now - expiry) & (t_now > _I32_MIN)
        exp_ranks = state.rank_of[order]
        exp_ok &= exp_ranks != EMPTY
        exp_slots = order
    else:
        exp_slots = jnp.zeros(batch, jnp.int32)
        exp_ranks = jnp.zeros(batch, jnp.int32)
        exp_ok = jnp.zeros(batch, bool)

    all_del = jnp.concatenate([jnp.where(del_ok, dranks, 0),
                               jnp.where(exp_ok, exp_ranks, 0)])
    all_del_mask = jnp.concatenate([del_ok, exp_ok])

    ins_lists = jnp.where(ins_ok[:, None], lists, EMPTY)
    ins_cards = jnp.where(ins_ok, cards, 0)
    ins_times = jnp.where(ins_ok, t, 0)

    if mode == "vertex":
        hg, counts, new_ranks, (vreg, vm) = U.vertex_churn_step(
            state.hg, state.counts, v_total, all_del, all_del_mask,
            ins_lists, ins_cards, ins_ok,
            max_nb=max_nb, max_region=max_region, chunk=chunk,
            backend=backend, mesh=mesh)
        times = state.times
        if track_dirty:
            # the edge-family dirty set is not a by-product of this mode's
            # counting — derive it from the batch seeds (old graph for the
            # delete side, new graph for the inserts)
            erd, emd = U.affected_edges(state.hg, all_del, all_del_mask,
                                        max_deg=max_deg,
                                        max_region=max_region)
            eri, emi = U.affected_edges(hg, new_ranks, ins_ok,
                                        max_deg=max_deg,
                                        max_region=max_region)
            ereg = jnp.concatenate([erd, eri])
            em = jnp.concatenate([emd, emi])
            e_sat = jnp.all(emd) | jnp.all(emi)
        v_sat = jnp.all(vm)
    else:
        hg, counts, times, new_ranks, (ereg, em) = U.churn_step(
            state.hg, state.counts, all_del, all_del_mask,
            ins_lists, ins_cards, ins_ok,
            max_deg=max_deg, max_region=max_region, chunk=chunk,
            temporal=(mode == "temporal"), times=state.times,
            ins_times=ins_times, window=window, backend=backend, mesh=mesh)
        if track_dirty:
            # dual of the vertex-mode case: the vertex-family dirty set
            # (the 1-hop vertex closure of the batch — DESIGN.md §3)
            vrd, vmd = U.affected_vertices(state.hg, all_del, all_del_mask,
                                           max_nb=max_nb,
                                           max_region=max_region)
            vri, vmi = U.affected_vertices(hg, new_ranks, ins_ok,
                                           max_nb=max_nb,
                                           max_region=max_region)
            vreg = jnp.concatenate([vrd, vri])
            vm = jnp.concatenate([vmd, vmi])
            v_sat = jnp.all(vmd) | jnp.all(vmi)
        e_sat = jnp.all(em)

    # Dirty-map maintenance.  The counted family's region is a free
    # by-product; the other family's closure is only derived when
    # track_dirty (pure-ingest workloads skip it).  A closure that
    # saturates its max_region bound may have been truncated
    # (update._dedupe_pad keeps a prefix silently), so saturation
    # conservatively dirties the whole map — the cache rule stays exact,
    # never optimistic.  With track_dirty=False the derived family is
    # simply always-dirty (whole-map bump each step).
    epoch = state.epoch + 1
    n_slots = state.hg.n_edge_slots
    nv = state.hg.num_vertices
    if mode == "vertex":
        v_dirty_epoch = state.v_dirty_epoch.at[
            jnp.where(vm, jnp.minimum(vreg, nv), nv)
        ].set(epoch, mode="drop")
        v_dirty_epoch = jnp.where(v_sat, epoch, v_dirty_epoch)
        if track_dirty:
            dirty_epoch = state.dirty_epoch.at[
                jnp.where(em, jnp.minimum(ereg, n_slots), n_slots)
            ].set(epoch, mode="drop")
            dirty_epoch = jnp.where(e_sat, epoch, dirty_epoch)
        else:
            dirty_epoch = jnp.full_like(state.dirty_epoch, epoch)
    else:
        dirty_epoch = state.dirty_epoch.at[
            jnp.where(em, jnp.minimum(ereg, n_slots), n_slots)
        ].set(epoch, mode="drop")
        dirty_epoch = jnp.where(e_sat, epoch, dirty_epoch)
        if track_dirty:
            v_dirty_epoch = state.v_dirty_epoch.at[
                jnp.where(vm, jnp.minimum(vreg, nv), nv)
            ].set(epoch, mode="drop")
            v_dirty_epoch = jnp.where(v_sat, epoch, v_dirty_epoch)
        else:
            v_dirty_epoch = jnp.full_like(state.v_dirty_epoch, epoch)

    # slot -> (rank, time) bookkeeping: clear deletions/expiries, then record
    # this batch's inserts (an insert reusing a just-freed slot wins)
    drop = lambda a, i, m, v: a.at[jnp.where(m, i, C)].set(v, mode="drop")
    rank_of = drop(state.rank_of, dslot, del_ok, EMPTY)
    live_t = drop(state.live_t, dslot, del_ok, EMPTY)
    rank_of = drop(rank_of, exp_slots, exp_ok, EMPTY)
    live_t = drop(live_t, exp_slots, exp_ok, EMPTY)

    # slot collision: an insert whose ring slot still tracks a live edge
    # *after* this batch's deletions/expiries — the edge outlived `capacity`
    # subsequent events; bookkeeping would be lost, so flag it sticky
    collide = jnp.any(ins_ok & (live_t[slot] != EMPTY))

    rank_of = rank_of.at[jnp.where(ins_ok, slot, C)].set(
        jnp.where(ins_ok, new_ranks, EMPTY), mode="drop")
    live_t = live_t.at[jnp.where(ins_ok, slot, C)].set(
        jnp.where(ins_ok, t, EMPTY), mode="drop")

    error = (state.error | log.error | hg.h2v.error | hg.v2h.error
             | collide.astype(jnp.int32) * ERR_SLOT_COLLISION)
    # first-trip epoch per error bit (decode_errors): a bit newly present
    # in ``error`` but not in ``state.error`` tripped at this batch
    newly = error & ~state.error
    bit = jnp.int32(1) << jnp.arange(N_ERR_BITS, dtype=jnp.int32)
    error_epoch = jnp.where((newly & bit) != 0, epoch, state.error_epoch)
    return StreamState(hg=hg, counts=counts, times=times, log=log,
                       rank_of=rank_of, live_t=live_t, t_now=t_now,
                       error=error, error_epoch=error_epoch, epoch=epoch,
                       dirty_epoch=dirty_epoch,
                       v_dirty_epoch=v_dirty_epoch)


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "batch", "mode", "max_deg", "max_nb",
                     "max_region", "chunk", "window", "expiry", "backend",
                     "mesh", "track_dirty"),
)
def _run_stream_scan(
    state: StreamState, *, n_steps, batch, mode, max_deg, max_nb,
    max_region, chunk, window, expiry, v_total, backend, mesh, track_dirty,
) -> StreamState:
    """The jitted fixed-capacity scan core: one XLA computation threading
    ``n_steps`` scheduler batches through the Alg. 3 single-batch step.
    ``run_stream`` wraps it (and, with ``auto_grow``, re-dispatches it per
    segment — capacities/heights are trace constants, so every growth is
    one fresh specialisation)."""

    def body(s, _):
        s = _stream_step(
            s, batch=batch, mode=mode, max_deg=max_deg, max_nb=max_nb,
            max_region=max_region, chunk=chunk, window=window, expiry=expiry,
            v_total=v_total, backend=backend, mesh=mesh,
            track_dirty=track_dirty)
        return s, None

    state, _ = jax.lax.scan(body, state, None, length=n_steps)
    return state


def _pad_to(arr: jax.Array, n: int, fill) -> jax.Array:
    if arr.shape[0] >= n:
        return arr
    return jnp.concatenate(
        [arr, jnp.full(n - arr.shape[0], fill, arr.dtype)])


def _compact_or_double(store, new_bits: int, max_capacity: int):
    """One deterministic capacity repair: compact always (folds Case-2
    chains, reclaims dead/leaked blocks), and double ``A`` unless
    compaction alone reclaims at least a quarter of it.  Re-running the
    same segment after a repair therefore either frees >= capacity/4 or
    doubles — the retry loop in ``run_stream`` cannot stall."""
    capacity = store.capacity
    if new_bits & ERR_CAPACITY:
        stats = E.store_stats(store)
        if (stats["used"] - stats["live"]) * 4 < capacity:
            capacity = min(2 * capacity, max_capacity)
    return E.compact_store(store, capacity=capacity)


def _repairable_bits(store, bits: int, max_capacity: int,
                     max_height: int) -> int:
    """The subset of ``bits`` a repair under the growth ceilings can still
    make progress on.  A bit whose only remedy is past its ceiling is
    demoted to non-growable — the segment is accepted with the sticky
    error instead of doubling forever (one corrupt vertex id must cost a
    decoded error, not an OOM)."""
    out = 0
    if bits & ERR_CAPACITY:
        stats = E.store_stats(store)
        can_reclaim = (stats["used"] - stats["live"]) * 4 >= store.capacity
        if store.capacity < max_capacity or can_reclaim:
            out |= ERR_CAPACITY
    if bits & ERR_RANKS and store.mgr.height < max_height:
        out |= ERR_RANKS
    return out


def _grow_checkpoint(ckpt: StreamState, h2v_bits: int, v2h_bits: int,
                     max_capacity: int, max_height: int) -> StreamState:
    """Repair a pre-error checkpoint so the failed segment can re-run:
    compact/grow each store that tripped (``_compact_or_double``), then
    pad the rank-indexed stream arrays (times / dirty maps) to the new
    universe.  Everything else — counts, log, ring bookkeeping, epochs —
    is untouched, which is what makes the re-run bit-identical."""
    h2v, v2h = ckpt.hg.h2v, ckpt.hg.v2h
    if h2v_bits & GROWABLE_ERRORS:
        if h2v_bits & ERR_RANKS and h2v.mgr.height < max_height:
            h2v = E.grow_store(h2v, levels=1)
        h2v = _compact_or_double(h2v, h2v_bits, max_capacity)
    if v2h_bits & GROWABLE_ERRORS:
        if v2h_bits & ERR_RANKS and v2h.mgr.height < max_height:
            # vertex universe exhausted: new ids come up registered
            v2h = E.grow_store(v2h, levels=1, register_ranks=True)
        v2h = _compact_or_double(v2h, v2h_bits, max_capacity)
    hg = Hypergraph(h2v=h2v, v2h=v2h)
    return dataclasses.replace(
        ckpt, hg=hg,
        times=_pad_to(ckpt.times, hg.n_edge_slots, 0),
        dirty_epoch=_pad_to(ckpt.dirty_epoch, hg.n_edge_slots, 0),
        v_dirty_epoch=_pad_to(ckpt.v_dirty_epoch, hg.num_vertices, 0),
    )


def run_stream(
    state: StreamState,
    *,
    n_steps: int,
    batch: int,
    mode: str = "edge",          # "edge" | "temporal" | "vertex"
    max_deg: int = 32,
    max_nb: int = 32,
    max_region: int = 1023,
    chunk: int = 1024,
    window: int | None = None,   # temporal triad span bound δ (counting)
    expiry: int | None = None,   # retention window (liveness; temporal mode)
    v_total: jax.Array | int = 0,
    backend: str | None = None,
    mesh=None,                   # jax.sharding.Mesh | None — sharded counts
    track_dirty: bool = True,    # maintain BOTH dirty maps exactly (§7.2);
                                 # False: skip the derived-family closure
                                 # (pure-ingest speed) — that map then
                                 # bumps wholesale every step, so its
                                 # point queries never cache across epochs
    auto_grow: bool = False,     # elastic mode: segment the scan, roll a
                                 # growable sticky error back to the last
                                 # segment boundary, compact/grow the
                                 # stores (core/elastic.py) and re-run
    segment: int | None = None,  # steps per checkpointed segment
                                 # (auto_grow only; default min(8, n_steps))
    max_grows: int = 64,         # growth-attempt bound (recompile budget)
    max_capacity: int = 1 << 28,  # per-store ceiling for capacity doubling
    max_height: int = 22,         # perfect-BST height ceiling (~4M ranks)
    grow_log: list | None = None,  # observability: one dict appended per
                                   # repair (step, tripped bits, new
                                   # capacities/heights) — fig21 reports it
) -> StreamState:
    """Scan ``n_steps`` scheduler batches through the Alg. 3 core.  One XLA
    computation end to end; counts stay exact after every step (validated in
    tests/test_stream.py).  Use ``plan_steps`` to size ``n_steps`` so the
    log fully drains, including the expiry backlog.  With ``mesh`` every
    step's affected-region counting shards across the mesh's devices
    (distributed/triads.py — DESIGN.md §6); results are bit-identical.
    ``backend`` reaches the fused probe kernel through the shared chunk
    lowerings (``"pallas"``/``"xla"``/``"bitset"``, or None to auto-select
    — kernels/ops.resolve_backend); histograms are backend-invariant
    (tests/test_backend_parity.py).

    With ``auto_grow=True`` the scan becomes a segmented driver over the
    same jitted core (DESIGN.md §8): every ``segment`` steps the sticky
    error is read back; a segment that trips a *growable* bit (store
    capacity / rank space — ``GROWABLE_ERRORS``) is discarded, the
    checkpointed stores are compacted and/or doubled
    (``elastic.compact_store`` / ``grow_store``), and the segment re-runs.
    Because growth preserves ranks and list contents exactly and the
    scheduler is deterministic, the resumed stream is bit-identical to one
    pre-sized at the final capacity (tests/test_elastic.py, fig21).
    Non-growable errors (``decode_errors`` names them) stay sticky exactly
    as in the fixed-capacity path, and so does a growable error whose
    repair would exceed the growth ceilings (``max_capacity`` slots per
    store / ``max_height`` tree levels): one corrupt vertex id demanding
    a 2^27-rank universe costs a decoded ``rank-space-exhausted`` error,
    not an exponential doubling to OOM.  Growth re-specialises the scan,
    so with G growth events the driver compiles O(G) times — size
    ``segment`` against your checkpoint-read-back tolerance, not the
    compile count.

    Dirty-map caveat: the maps inherit the repo-wide bound contract —
    per-row neighbourhoods truncate silently past ``max_deg``/``max_nb``
    (docs/API.md), so BOTH bounds must be sized from your data even in
    modes that only count one family (vertex mode derives its edge dirty
    map through ``max_deg``; edge/temporal modes derive the vertex map
    through ``max_nb``).  Region-level saturation, by contrast, is
    detected and dirties conservatively."""
    if mode not in ("edge", "temporal", "vertex"):
        raise ValueError(f"unknown mode {mode!r}")
    if batch > state.log.capacity:
        raise ValueError(
            f"batch={batch} exceeds log capacity {state.log.capacity}: "
            "two events of one batch would share a ring slot")
    kw = dict(batch=batch, mode=mode, max_deg=max_deg, max_nb=max_nb,
              max_region=max_region, chunk=chunk, window=window,
              expiry=expiry, v_total=v_total, backend=backend, mesh=mesh,
              track_dirty=track_dirty)
    if not auto_grow:
        return _run_stream_scan(state, n_steps=n_steps, **kw)

    seg = max(1, min(segment or 8, n_steps))
    done, grows = 0, 0
    while done < n_steps:
        k = min(seg, n_steps - done)
        ckpt = state
        out = _run_stream_scan(state, n_steps=k, **kw)
        # only bits NEW relative to the checkpoint trigger a repair — a
        # pre-existing sticky error is the caller's to interpret — and
        # only while the growth ceilings leave the repair room to make
        # progress (past them the bit is sticky, same as auto_grow=False)
        h2v_bits = _repairable_bits(
            ckpt.hg.h2v,
            int(out.hg.h2v.error) & ~int(ckpt.hg.h2v.error),
            max_capacity, max_height)
        v2h_bits = _repairable_bits(
            ckpt.hg.v2h,
            int(out.hg.v2h.error) & ~int(ckpt.hg.v2h.error),
            max_capacity, max_height)
        if (h2v_bits | v2h_bits) & GROWABLE_ERRORS:
            grows += 1
            if grows > max_grows:
                raise RuntimeError(
                    f"auto_grow exceeded max_grows={max_grows} repairs "
                    f"(last segment tripped h2v={h2v_bits:#x} "
                    f"v2h={v2h_bits:#x}); raise max_grows or pre-size")
            state = _grow_checkpoint(ckpt, h2v_bits, v2h_bits,
                                     max_capacity, max_height)
            if grow_log is not None:
                grow_log.append({
                    "epoch": int(ckpt.epoch),
                    "step": done, "h2v_bits": h2v_bits,
                    "v2h_bits": v2h_bits,
                    "h2v_capacity": state.hg.h2v.capacity,
                    "v2h_capacity": state.hg.v2h.capacity,
                    "h2v_height": state.hg.h2v.mgr.height,
                    "v2h_height": state.hg.v2h.mgr.height,
                })
            continue                      # re-run the same segment
        state = out
        done += k
    return state


@dataclasses.dataclass(frozen=True)
class StreamError:
    """One decoded sticky-error bit: which flag, its human name, and the
    epoch (1-based applied-batch count) at which it first tripped —
    ``epoch == -1`` means the bit was already set in the initial state."""
    flag: int
    name: str
    epoch: int


def decode_errors(state: StreamState) -> list[StreamError]:
    """Host-side decoder for ``StreamState.error``: one ``StreamError`` per
    set bit, in ``ERROR_FLAGS`` order.  An empty list means the run is
    clean; ``state.error`` stays the cheap device-side scalar (tests can
    still assert ``int(state.error) == 0``), this is the debugging view —
    *which* invariant broke and *at which batch* — that a bare int32
    cannot give."""
    err = int(state.error)
    if err == 0:
        return []
    epochs = np.asarray(state.error_epoch)
    return [StreamError(flag=flag, name=name, epoch=int(epochs[i]))
            for i, (flag, name) in enumerate(ERROR_FLAGS) if err & flag]


def plan_steps(events, batch: int, *, expiry: int | None = None) -> int:
    """Host-side dry run of the scheduler: the exact number of ``run_stream``
    steps needed to consume ``events`` *and* drain the expiry backlog.
    Mirrors ``_pop_batch``'s consistency barrier and the per-step expiry
    bound, so a scan of this length always finishes the stream."""
    n = len(events)
    head, steps = 0, 0
    live: dict[int, int] = {}      # event index -> timestamp
    t_now = None

    def n_expired():
        if expiry is None or t_now is None:
            return 0
        return sum(1 for ti in live.values() if ti <= t_now - expiry)

    while head < n or n_expired() > 0:
        steps += 1
        take = 0
        for off in range(min(batch, n - head)):
            i = head + off
            ti, k, payload = events[i]
            if k == "del" and head <= payload < i:
                break                     # consistency barrier
            take += 1
        popped = events[head : head + take]
        for i, (ti, k, payload) in enumerate(popped, start=head):
            t_now = ti if t_now is None else max(t_now, ti)
            if k == "del" and payload in live:
                del live[payload]
        # expiry selects from the pre-insert live set, exactly as the device
        # step does (this batch's inserts become expirable next step)
        if expiry is not None and t_now is not None:
            expired = sorted(
                (i for i, ti in live.items() if ti <= t_now - expiry),
                key=lambda i: live[i])[:batch]
            for i in expired:
                del live[i]
        for i, (ti, k, payload) in enumerate(popped, start=head):
            if k == "ins":
                live[i] = ti
        head += take
        if take == 0 and head < n:        # cannot happen; guard the loop
            raise RuntimeError("scheduler stalled")
    return steps
