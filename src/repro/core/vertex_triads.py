"""Incident-vertex triad counting, StatHyper types 1/2/3 (paper Fig. 2b).

For a triple of distinct vertices {u, v, w}, a pair is *connected* when some
hyperedge contains both.  Types:

  Type 1 — closed and covered: some single hyperedge contains all three
           (all 3 pairs in the same hyperedge);
  Type 2 — open: exactly 1 or 2 of the three pairs are connected;
  Type 3 — closed but not covered: all 3 pairs connected, yet no hyperedge
           contains all three (each pair through different hyperedges).

Counting strategy (exact, region-aware):
  * build the co-occurrence graph G on region vertices (padded adjacency);
  * triangles of G enumerated once ((u,v) edge, w ∈ N(u) ∩ N(v), w > v);
    per triangle n_uvw = |E_u ∩ E_v ∩ E_w| via the triple-intersection
    kernel over v2h rows → splits C3 into Type 1 / Type 3;
  * wedges: C2 = Σ_v C(degG(v), 2) − 3·C3  (exactly-2-pair triples);
  * singles: S1 = |edges(G)|·(V_total − 2) counts each triple once per
    connected pair ⇒ C1 = S1 − 2·C2 − 3·C3; Type 2 = C1 + C2.

``v_total`` is the *global* vertex count so that Alg. 3 deltas of the
region-restricted count telescope exactly (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import blockmgr as bm
from repro.core.hypergraph import Hypergraph
from repro.core.store import EMPTY, dedupe_sorted, read_dense, read_sorted


def vertex_neighbors(hg: Hypergraph, vids: jax.Array, max_nb: int) -> jax.Array:
    """Co-occurrence neighbours of each vertex (dedup, self-free, padded)."""
    hl = read_dense(hg.v2h, vids)                       # [m, vdeg]
    m, vdeg = hl.shape
    flat_h = jnp.where(hl == EMPTY, 0, hl).reshape(-1)
    members = read_dense(hg.h2v, flat_h).reshape(m, vdeg, -1)
    cand = jnp.where((hl == EMPTY)[:, :, None], EMPTY, members).reshape(m, -1)
    cand = jnp.where(cand == vids[:, None], EMPTY, cand)
    return dedupe_sorted(cand)[:, :max_nb]


def vertex_worklist(hg: Hypergraph, region_vids, region_mask, *, max_nb: int):
    """Region-level vertex pair work-list (DESIGN.md §3.2): the co-occurrence
    adjacency restricted to the region, the closed-form wedge/edge terms, and
    the flattened ``(u, v)`` pair list the triangle kernel consumes.  Shared
    lowering between ``count_vertex_triads`` and the sharded driver in
    ``distributed/triads.py``.

    Returns ``(bitmap, u, v, ok, n_edges, wedges)`` with ``u/v/ok`` the
    unpadded flat pair arrays of length ``R * max_nb``."""
    nv = hg.num_vertices
    bitmap = jnp.zeros(nv + 1, jnp.int32)
    safe = jnp.where(region_mask, jnp.minimum(region_vids, nv), nv)
    bitmap = bitmap.at[safe].set(1).at[nv].set(0)
    vids = jnp.where(region_mask, region_vids, 0)

    nbrs = vertex_neighbors(hg, vids, max_nb)           # [R, K]
    keep = (nbrs != EMPTY) & (bitmap[jnp.minimum(nbrs, nv)] == 1)
    nbrs = jnp.where(keep, nbrs, EMPTY)
    R, K = nbrs.shape

    deg = jnp.sum((nbrs != EMPTY) & region_mask[:, None], axis=1)
    n_edges = jnp.sum(deg) // 2                         # each edge seen twice
    wedges = jnp.sum(deg * (deg - 1) // 2)

    u_flat = jnp.repeat(vids, K)
    w_mask = jnp.repeat(region_mask, K)
    v_flat = nbrs.reshape(-1)
    pair_ok = w_mask & (v_flat != EMPTY) & (v_flat > u_flat)
    v_safe = jnp.where(pair_ok, v_flat, 0)
    return bitmap, u_flat, v_safe, pair_ok, n_edges, wedges


def chunk_triangles(hg: Hypergraph, bitmap, *, max_nb: int, chunk: int,
                    backend):
    """Per-chunk triangle kernel: ``(u, v, ok)`` int32[chunk] pairs ->
    ``[triangles, covered-triangles]`` partial sums.  Factored out of
    ``count_vertex_triads`` so the sharded driver runs the identical kernel
    on its local slice of the pair list.

    The intersection hot spot is ONE kernel launch per chunk: only the
    triple size |Eu∩Ev∩Ew| feeds the covered-triangle test, so this uses
    ``kops.triple_intersect_count`` (membership fused in-kernel) rather
    than the four-output fused_triple_stats — same single launch, none of
    the discarded iab/iac/ibc tile work.  The universe here is *hyperedge
    ranks*, so the bitset backend packs against ``hg.n_edge_slots``."""
    from repro.kernels import ops as kops

    nv = hg.num_vertices
    n_bits = hg.n_edge_slots
    backend = kops.resolve_backend(backend, c=hg.v2h.max_card, n_bits=n_bits)

    def one_chunk(args):
        u, v, ok = args
        nu = vertex_neighbors(hg, u, max_nb)
        nv_ = vertex_neighbors(hg, v, max_nb)
        # w ∈ N(u) ∩ N(v), w > v, region-restricted
        in_nv = jnp.any(
            (nu[:, :, None] == nv_[:, None, :]) & (nv_[:, None, :] != EMPTY), axis=2
        )
        w_cand = jnp.where(
            in_nv & (nu != EMPTY) & (nu > v[:, None])
            & (bitmap[jnp.minimum(nu, nv)] == 1),
            nu, EMPTY,
        )
        Eu = read_sorted(hg.v2h, u)                     # hyperedges of u
        Ev = read_sorted(hg.v2h, v)
        w_safe = jnp.where(w_cand == EMPTY, 0, w_cand)
        Ew = read_sorted(hg.v2h, w_safe.reshape(-1)).reshape(chunk, w_cand.shape[1], -1)
        nuvw = kops.triple_intersect_count(
            Eu, Ev, Ew, backend=backend, n_bits=n_bits, assume_sorted=True)
        tri_ok = ok[:, None] & (w_cand != EMPTY)
        t_all = jnp.sum(tri_ok)
        t_covered = jnp.sum(tri_ok & (nuvw > 0))
        return jnp.stack([t_all, t_covered])

    return one_chunk


def combine_counts(c3, covered, n_edges, wedges, v_total):
    """Closed-form assembly of the (type1, type2, type3) histogram from the
    triangle partials and the region-level wedge/edge terms (module
    docstring).  Runs on replicated values after the psum merge in the
    sharded driver."""
    type1 = covered
    type3 = c3 - covered
    c2 = wedges - 3 * c3
    s1 = n_edges * (jnp.asarray(v_total, jnp.int32) - 2)
    c1 = s1 - 2 * c2 - 3 * c3
    type2 = c1 + c2
    return jnp.stack([type1, type2, type3]).astype(jnp.int32)


def point_region(hg: Hypergraph, vids: jax.Array, mask: jax.Array, *,
                 max_nb: int):
    """Per-query closed co-occurrence neighbourhoods ``N[v] = {v} ∪ N(v)``
    — the region a per-vertex point query counts over (DESIGN.md §7).
    Returns ``(region_vids, region_mask)`` of shape ``[M, max_nb + 1]``."""
    nb = vertex_neighbors(hg, jnp.where(mask, vids, 0), max_nb)   # [M, K]
    nb = jnp.where(mask[:, None], nb, EMPTY)
    region = jnp.concatenate([vids[:, None], nb], axis=1)         # [M, K+1]
    rmask = jnp.concatenate([mask[:, None], nb != EMPTY], axis=1)
    return region, rmask


def point_worklists(hg: Hypergraph, vids: jax.Array, mask: jax.Array, *,
                    max_nb: int):
    """Batched per-query pair work-lists: ``vertex_worklist`` vmapped over
    the M closed neighbourhoods ``N[v]``, flattened into one probe list with
    per-probe query ids so the whole batch costs one padded kernel launch
    per chunk (the query-service hot path, DESIGN.md §7).

    Returns ``(bitmaps [M, nv+1], qi, u, v, ok, n_edges [M], wedges [M])``
    with ``qi/u/v/ok`` flat arrays of length ``M·(max_nb+1)·max_nb``."""
    region, rmask = point_region(hg, vids, mask, max_nb=max_nb)
    wl = jax.vmap(
        lambda rv, rm: vertex_worklist(
            hg, jnp.where(rm, rv, 0), rm, max_nb=max_nb),
        in_axes=(0, 0),
    )(region, rmask)
    bitmaps, u, v, ok, n_edges, wedges = wl          # [M, …] each
    M, P = u.shape
    qi = jnp.broadcast_to(
        jnp.arange(M, dtype=jnp.int32)[:, None], (M, P)).reshape(-1)
    return (bitmaps, qi, u.reshape(-1), v.reshape(-1), ok.reshape(-1),
            n_edges, wedges)


def point_chunk_triangles(hg: Hypergraph, bitmaps, *, max_nb: int,
                          chunk: int, backend, n_queries: int):
    """Per-chunk triangle kernel for batched point queries: identical
    arithmetic to ``chunk_triangles`` except each probe restricts its
    w-candidates through its own query's region bitmap (``bitmaps[qi]``)
    and the partial sums scatter per query.  ``(qi, u, v, ok)`` int32[chunk]
    -> int32[n_queries, 2] (triangles, covered-triangles)."""
    from repro.kernels import ops as kops

    nv = hg.num_vertices
    n_bits = hg.n_edge_slots
    backend = kops.resolve_backend(backend, c=hg.v2h.max_card, n_bits=n_bits)

    def one_chunk(args):
        qi, u, v, ok = args
        bm_rows = bitmaps[qi]                           # [chunk, nv+1]
        nu = vertex_neighbors(hg, u, max_nb)
        nv_ = vertex_neighbors(hg, v, max_nb)
        in_nv = jnp.any(
            (nu[:, :, None] == nv_[:, None, :]) & (nv_[:, None, :] != EMPTY), axis=2
        )
        in_region = jnp.take_along_axis(
            bm_rows, jnp.minimum(nu, nv), axis=1) == 1
        w_cand = jnp.where(
            in_nv & (nu != EMPTY) & (nu > v[:, None]) & in_region,
            nu, EMPTY,
        )
        Eu = read_sorted(hg.v2h, u)
        Ev = read_sorted(hg.v2h, v)
        w_safe = jnp.where(w_cand == EMPTY, 0, w_cand)
        Ew = read_sorted(hg.v2h, w_safe.reshape(-1)).reshape(
            chunk, w_cand.shape[1], -1)
        nuvw = kops.triple_intersect_count(
            Eu, Ev, Ew, backend=backend, n_bits=n_bits, assume_sorted=True)
        tri_ok = ok[:, None] & (w_cand != EMPTY)
        per_row = jnp.stack(
            [jnp.sum(tri_ok, axis=1),
             jnp.sum(tri_ok & (nuvw > 0), axis=1)], axis=1)      # [chunk, 2]
        q_safe = jnp.where(ok, qi, n_queries)   # n_queries = oob -> drop
        return jnp.zeros((n_queries, 2), jnp.int32).at[q_safe].add(
            per_row, mode="drop")

    return one_chunk


@functools.partial(jax.jit, static_argnames=("max_nb", "chunk", "backend"))
def count_vertex_triads_at(
    hg: Hypergraph,
    vids: jax.Array,          # int32[M] query vertex ids
    mask: jax.Array,          # bool[M]
    v_total: jax.Array | int,
    *,
    max_nb: int,
    chunk: int = 1024,
    backend: str | None = None,
) -> jax.Array:
    """Batched per-vertex point queries: row q is the (type1, type2, type3)
    histogram of ``count_vertex_triads`` over the closed neighbourhood
    region ``N[vids[q]]`` — the local triad participation of the query
    vertex (DESIGN.md §7).  Bit-identical to calling ``count_vertex_triads``
    with ``point_region``'s row q, but the M pair work-lists concatenate
    into one padded kernel launch per chunk instead of M jit dispatches.
    Masked-off rows are zero.  Returns int32[M, 3]."""
    from repro.core.triads import pad_probes

    M = vids.shape[0]
    bitmaps, qi, u, v, ok, n_edges, wedges = point_worklists(
        hg, vids, mask, max_nb=max_nb)
    (qi, u, v), ok = pad_probes([qi, u, v], ok, chunk)
    nchunk = qi.shape[0] // chunk

    one_chunk = point_chunk_triangles(hg, bitmaps, max_nb=max_nb,
                                      chunk=chunk, backend=backend,
                                      n_queries=M)
    per = jax.lax.map(
        one_chunk,
        (qi.reshape(nchunk, chunk), u.reshape(nchunk, chunk),
         v.reshape(nchunk, chunk), ok.reshape(nchunk, chunk)),
    )
    c3, covered = jnp.sum(per, axis=0).T                  # int32[M] each
    hist = jax.vmap(combine_counts, in_axes=(0, 0, 0, 0, None))(
        c3, covered, n_edges, wedges, v_total)
    return jnp.where(mask[:, None], hist, 0)


@functools.partial(jax.jit, static_argnames=("max_nb", "chunk", "backend"))
def count_vertex_triads(
    hg: Hypergraph,
    region_vids: jax.Array,   # int32[R]
    region_mask: jax.Array,   # bool[R]
    v_total: jax.Array | int, # global |V| (live vertices)
    *,
    max_nb: int,
    chunk: int = 1024,
    backend: str | None = None,
) -> jax.Array:
    """Returns int32[3] = (type1, type2, type3) for triples whose connected
    pairs lie inside the region (see module docstring for semantics)."""
    from repro.core.triads import pad_pairs

    bitmap, u_flat, v_safe, pair_ok, n_edges, wedges = vertex_worklist(
        hg, region_vids, region_mask, max_nb=max_nb)
    u_flat, v_safe, pair_ok = pad_pairs(u_flat, v_safe, pair_ok, chunk)
    nchunk = u_flat.shape[0] // chunk

    one_chunk = chunk_triangles(hg, bitmap, max_nb=max_nb, chunk=chunk,
                                backend=backend)
    per = jax.lax.map(
        one_chunk,
        (
            u_flat.reshape(nchunk, chunk),
            v_safe.reshape(nchunk, chunk),
            pair_ok.reshape(nchunk, chunk),
        ),
    )
    c3, covered = jnp.sum(per, axis=0)
    return combine_counts(c3, covered, n_edges, wedges, v_total)
