"""Vertical and horizontal batch operations on an EscherStore (paper §III-B).

Every op is a pure function ``store -> store`` over fixed-shape batches with
a validity mask; jitted callers donate the store so XLA updates in place.

Vertical ops  : hyperedge deletion (Alg. 1) and insertion (Alg. 2 + the
                three cases of Fig. 5).
Horizontal ops: incident-vertex insertion/deletion, grouped by list id the
                way the paper serialises each group onto one thread — here
                each *round* applies at most one update per list, rounds run
                until the batch drains.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import blockmgr as bm
from repro.core.store import (
    EMPTY, END, ERR_CAPACITY, ERR_RANKS, ERR_ROW_FULL, EscherStore,
    block_size, encode_ptr)


# --------------------------------------------------------------------------
# Vertical: deletion
# --------------------------------------------------------------------------
def delete_hyperedges(store: EscherStore, ranks: jax.Array, mask: jax.Array) -> EscherStore:
    """Paper Alg. 1: O(1) bookkeeping per deletion — mark the tree node
    available and propagate ``avail``.  Block contents stay untouched until
    the block is reused (no per-element clearing)."""
    mgr = bm.mark_delete(store.mgr, ranks, mask)
    return dataclasses.replace(store, mgr=mgr)


# --------------------------------------------------------------------------
# Vertical: insertion (cases 1-3 of Fig. 5)
# --------------------------------------------------------------------------
def insert_hyperedges(
    store: EscherStore,
    lists: jax.Array,   # int32[m, max_card], EMPTY-padded
    cards: jax.Array,   # int32[m]
    mask: jax.Array,    # bool[m]
) -> tuple[EscherStore, jax.Array]:
    """Batch hyperedge insertion. Returns (store, assigned_ranks[m]).

    Case 1: the first ``root_avail`` insertions reuse freed blocks located by
            the parallel k-th-available descent (Alg. 2); the new hyperedge
            takes over the freed node (ID reuse, no rebalancing).
    Case 2: a reused block too small for the new cardinality gets ONE
            overflow block bump-allocated from the free tail and chained via
            the metadata slot.
    Case 3: insertions beyond the available blocks get fresh blocks whose
            starting addresses come from a parallel prefix sum; their tree
            nodes are the pre-padded dummy slots of the perfect tree, so the
            paper's "full reconstruction" is a pure activation here.
    """
    m, max_card = lists.shape
    granule = store.granule
    mgr = store.mgr
    cards = cards.astype(jnp.int32)

    navail = mgr.root_avail
    k = jnp.cumsum(mask.astype(jnp.int32))                   # 1-based among valid
    reuse = mask & (k <= navail)
    fresh = mask & ~reuse

    # ---- Case 1: locate + claim the k-th available nodes
    reuse_idx = bm.find_kth_available(mgr, jnp.where(reuse, k, 1))
    reuse_idx = jnp.where(reuse, reuse_idx, 0)
    mgr = bm.claim_nodes(mgr, jnp.where(reuse, reuse_idx, 1), reuse)

    # ---- Case 3: fresh ranks activate dummy slots in rank order
    fresh_ord = jnp.cumsum(fresh.astype(jnp.int32)) - 1      # 0-based among fresh
    fresh_rank = store.n_ranks + fresh_ord
    slot_of_rank = (1 << mgr.height) - 1
    rank_overflow = fresh & (fresh_rank >= slot_of_rank)
    fresh_rank = jnp.minimum(fresh_rank, slot_of_rank - 1)
    fresh_idx = bm.cbt_index(jnp.maximum(fresh_rank, 0), mgr.height)
    fresh_idx = jnp.where(fresh, fresh_idx, 0)

    node_idx = jnp.where(reuse, reuse_idx, fresh_idx)
    ranks_out = jnp.where(mask, mgr.hid[node_idx], -1)

    # ---- capacity planning per insertion
    old_cap0 = mgr.cap0[node_idx]
    old_a1 = mgr.addr1[node_idx]
    old_cap1 = mgr.cap1[node_idx]
    need_fresh_primary = fresh
    # fresh primary block holds the whole list (single block, Case 3)
    fresh_size = block_size(cards, granule)
    # reused: usable = (cap0-1) + (cap1-1 if chained); overflow if short
    usable_reuse = (old_cap0 - 1) + jnp.where(old_a1 >= 0, old_cap1 - 1, 0)
    need_over = reuse & (cards > usable_reuse)
    over_size = block_size(jnp.maximum(cards - (old_cap0 - 1), 0), granule)

    # ---- bump allocation from the free tail via prefix sum (CUDA Thrust -> cumsum)
    alloc_size = jnp.where(need_fresh_primary, fresh_size, 0) + jnp.where(need_over, over_size, 0)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(alloc_size, dtype=jnp.int32)])
    base = store.free_ptr
    alloc_start = base + offs[:-1]
    new_free = base + offs[-1]
    cap_overflow = new_free > store.capacity
    error = (store.error
             | jnp.int32(cap_overflow) * ERR_CAPACITY
             | jnp.int32(jnp.any(rank_overflow)) * ERR_RANKS)

    a0 = jnp.where(need_fresh_primary, alloc_start, mgr.addr0[node_idx])
    c0 = jnp.where(need_fresh_primary, fresh_size, old_cap0)
    a1 = jnp.where(need_over, alloc_start, jnp.where(fresh, -1, old_a1))
    c1 = jnp.where(need_over, over_size, jnp.where(fresh, 0, old_cap1))

    # ---- write node table
    safe = jnp.where(mask, node_idx, 0)
    mgr = dataclasses.replace(
        mgr,
        addr0=mgr.addr0.at[safe].set(jnp.where(mask, a0, mgr.addr0[safe])),
        cap0=mgr.cap0.at[safe].set(jnp.where(mask, c0, mgr.cap0[safe])),
        addr1=mgr.addr1.at[safe].set(jnp.where(mask, a1, mgr.addr1[safe])),
        cap1=mgr.cap1.at[safe].set(jnp.where(mask, c1, mgr.cap1[safe])),
        card=mgr.card.at[safe].set(jnp.where(mask, cards, mgr.card[safe])),
        present=mgr.present.at[safe].max(mask.astype(jnp.int32)),
    )
    mgr = dataclasses.replace(
        mgr,
        present=mgr.present.at[0].set(0),
        deleted=mgr.deleted.at[0].set(0),
    )

    # ---- scatter the vertex payloads (primary then overflow positions)
    A = store.A
    slot = jnp.arange(max_card, dtype=jnp.int32)[None, :]
    u0 = c0[:, None] - 1
    pos = jnp.where(slot < u0, a0[:, None] + slot, a1[:, None] + (slot - u0))
    ok = mask[:, None] & (slot < cards[:, None])
    pos = jnp.where(ok, pos, store.capacity)
    A = A.at[pos.reshape(-1)].set(lists.reshape(-1), mode="drop")
    # wipe stale tail slots of reused blocks up to usable capacity
    tail_ok = mask[:, None] & (slot >= cards[:, None]) & (slot < (c0[:, None] - 1) + jnp.where(a1[:, None] >= 0, c1[:, None] - 1, 0))
    tail_pos = jnp.where(tail_ok, jnp.where(slot < u0, a0[:, None] + slot, a1[:, None] + (slot - u0)), store.capacity)
    A = A.at[tail_pos.reshape(-1)].set(EMPTY, mode="drop")
    # metadata: primary end -> chain pointer or END; overflow end -> END.
    # Zero-capacity primaries (c0 == 0: a compacted-away block, or a
    # lazily-registered list — core/elastic.py) have no metadata slot;
    # guard the write or ``a0 + c0 - 1 = -2`` wraps onto the tail.
    meta0 = jnp.where(a1 >= 0, encode_ptr(a1), END)
    A = A.at[jnp.where(mask & (c0 > 0), a0 + c0 - 1, store.capacity)].set(meta0, mode="drop")
    A = A.at[jnp.where(mask & (a1 >= 0), a1 + c1 - 1, store.capacity)].set(END, mode="drop")

    n_ranks = store.n_ranks + jnp.sum(fresh.astype(jnp.int32))
    return (
        dataclasses.replace(store, A=A, mgr=mgr, free_ptr=new_free, n_ranks=n_ranks, error=error),
        ranks_out,
    )


# --------------------------------------------------------------------------
# Horizontal: incident vertex insertion / deletion
# --------------------------------------------------------------------------
def _write_rows(store: EscherStore, node_idx, rows, cards, mask) -> EscherStore:
    """Write whole (padded) rows back through the chain, growing the overflow
    block when the new cardinality does not fit (horizontal overflow)."""
    mgr = store.mgr
    granule = store.granule
    m, max_card = rows.shape
    a0 = mgr.addr0[node_idx]
    c0 = mgr.cap0[node_idx]
    a1 = mgr.addr1[node_idx]
    c1 = mgr.cap1[node_idx]
    usable = (c0 - 1) + jnp.where(a1 >= 0, c1 - 1, 0)
    need_grow = mask & (cards > usable)
    # replacement overflow sized for the full remainder (old overflow leaks —
    # same trade the paper makes when chaining from the free chunk)
    grow_size = block_size(jnp.maximum(cards - (c0 - 1), 0), granule)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(jnp.where(need_grow, grow_size, 0), dtype=jnp.int32)])
    alloc_start = store.free_ptr + offs[:-1]
    new_free = store.free_ptr + offs[-1]
    error = store.error | jnp.int32(new_free > store.capacity) * ERR_CAPACITY

    a1 = jnp.where(need_grow, alloc_start, a1)
    c1 = jnp.where(need_grow, grow_size, c1)

    safe = jnp.where(mask, node_idx, 0)
    mgr = dataclasses.replace(
        mgr,
        addr1=mgr.addr1.at[safe].set(jnp.where(mask, a1, mgr.addr1[safe])),
        cap1=mgr.cap1.at[safe].set(jnp.where(mask, c1, mgr.cap1[safe])),
        card=mgr.card.at[safe].set(jnp.where(mask, cards, mgr.card[safe])),
    )

    A = store.A
    slot = jnp.arange(max_card, dtype=jnp.int32)[None, :]
    u0 = c0[:, None] - 1
    pos = jnp.where(slot < u0, a0[:, None] + slot, a1[:, None] + (slot - u0))
    ok = mask[:, None] & (slot < usable_rows_limit(c0, c1, a1)[:, None])
    pos = jnp.where(ok, pos, store.capacity)
    A = A.at[pos.reshape(-1)].set(rows.reshape(-1), mode="drop")
    # zero-capacity primaries (c0 == 0) carry no metadata slot — see
    # insert_hyperedges; the chain pointer lives only in the node table
    meta0 = jnp.where(a1 >= 0, encode_ptr(a1), END)
    A = A.at[jnp.where(mask & (c0 > 0), a0 + c0 - 1, store.capacity)].set(meta0, mode="drop")
    A = A.at[jnp.where(mask & (a1 >= 0), a1 + c1 - 1, store.capacity)].set(END, mode="drop")
    return dataclasses.replace(store, A=A, mgr=mgr, free_ptr=new_free, error=error)


def usable_rows_limit(c0, c1, a1):
    return (c0 - 1) + jnp.where(a1 >= 0, c1 - 1, 0)


def _apply_one_round(store: EscherStore, ranks, vids, is_insert, mask):
    """At most one update per hyperedge: read row, edit, write back."""
    from repro.core.store import read_dense

    node_idx = bm.cbt_index(jnp.maximum(ranks, 0), store.mgr.height)
    node_idx = jnp.where(mask, node_idx, 0)
    rows = read_dense(store, jnp.where(mask, ranks, 0))
    cards = store.mgr.card[node_idx]
    max_card = rows.shape[1]
    slot = jnp.arange(max_card, dtype=jnp.int32)[None, :]

    # deletion: blank the first slot holding vid, then stable-compact
    hit = (rows == vids[:, None]) & (slot < cards[:, None])
    first_hit = jnp.argmax(hit, axis=1)
    found = jnp.any(hit, axis=1) & ~is_insert & mask
    rows_del = jnp.where(
        (slot == first_hit[:, None]) & found[:, None], EMPTY, rows
    )
    order = jnp.argsort(rows_del == EMPTY, axis=1, stable=True)
    rows_del = jnp.take_along_axis(rows_del, order, axis=1)

    # insertion: append at position card (skip if already member or full)
    already = jnp.any((rows == vids[:, None]) & (slot < cards[:, None]), axis=1)
    can_ins = is_insert & mask & ~already & (cards < max_card)
    rows_ins = jnp.where(
        (slot == cards[:, None]) & can_ins[:, None], vids[:, None], rows_del
    )
    new_cards = cards - found.astype(jnp.int32) + can_ins.astype(jnp.int32)
    touched = mask & (found | can_ins)
    full = is_insert & mask & ~already & (cards >= max_card)
    store = dataclasses.replace(
        store, error=store.error | jnp.int32(jnp.any(full)) * ERR_ROW_FULL)
    return _write_rows(store, node_idx, rows_ins, new_cards, touched)


def apply_vertex_updates(
    store: EscherStore,
    ranks: jax.Array,      # int32[m] target list (hyperedge for h2v)
    vids: jax.Array,       # int32[m] vertex to insert/delete
    is_insert: jax.Array,  # bool[m]
    mask: jax.Array,       # bool[m]
) -> EscherStore:
    """Batch horizontal update.  Updates are grouped by list id (the paper
    runs one thread per group); round r applies the r-th update of every
    group simultaneously, looping until the deepest group drains.

    A target outside the store's rank universe (e.g. a vertex id beyond
    ``num_vertices`` reaching the v2h store) is masked out and sets the
    growable ``ERR_RANKS`` bit instead of letting ``cbt_index`` scribble
    on another list's node — ``run_stream(auto_grow=True)`` answers it by
    growing the tree a level (vertex-universe growth, DESIGN.md §8.1)."""
    n_univ = (1 << store.mgr.height) - 1
    oob = mask & ((ranks < 0) | (ranks >= n_univ))
    store = dataclasses.replace(
        store, error=store.error | jnp.int32(jnp.any(oob)) * ERR_RANKS)
    mask = mask & ~oob
    ranks = jnp.clip(ranks, 0, n_univ - 1)
    m = ranks.shape[0]
    keys = jnp.where(mask, ranks, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(keys, stable=True)
    r_s, v_s, i_s, m_s = ranks[order], vids[order], is_insert[order], mask[order]
    k_s = keys[order]                       # sorted grouping keys (masked -> MAX)
    pos = jnp.arange(m, dtype=jnp.int32)
    # within-group rank = position - first position of the group (sorted keys)
    first = jnp.searchsorted(k_s, k_s, side="left").astype(jnp.int32)
    within = pos - first
    n_rounds = jnp.max(jnp.where(m_s, within, 0)) + 1

    def cond(state):
        store, r = state
        return r < n_rounds

    def body(state):
        store, r = state
        sel = m_s & (within == r)
        store = _apply_one_round(store, r_s, v_s, i_s, sel)
        return store, r + 1

    store, _ = jax.lax.while_loop(cond, body, (store, jnp.int32(0)))
    return store
