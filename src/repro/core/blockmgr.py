"""Array-backed complete-binary-search-tree block manager (paper §III-A).

The paper stores one node per hyperedge in a *complete* BST laid out as an
array (heap order), each node carrying ``(h_id, start_addr, avail)`` where
``avail`` counts free (deleted) blocks in the node's subtree.  We adapt the
tree to a *perfect* BST padded to ``2^h - 1`` slots (dummy nodes carry
``present=0, avail=0``): this makes the paper's Eq. (1) parallel placement a
branch-free bit trick, keeps every shape static for XLA, and lets "tree
reconstruction" (insertion Case 3) degenerate into activating pre-existing
dummy slots — no data movement.  See DESIGN.md §2.

Node arrays are 1-indexed heap layout and allocated with size ``2^(h+1)`` so
children indices ``2i, 2i+1`` are always in-bounds (the phantom bottom level
is permanently ``avail=0``), removing bounds checks from the hot loops.

Local hyperedge IDs ("ranks") are consecutive integers ``0..n-1`` and double
as the in-order position in the tree, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

INVALID = jnp.iinfo(jnp.int32).max  # sentinel hyperedge id for dummy nodes


def tree_height(max_edges: int) -> int:
    """Height h such that a perfect tree with 2^h - 1 nodes fits max_edges."""
    return max(1, math.ceil(math.log2(max_edges + 1)))


def cbt_index(rank, height: int):
    """Closed-form heap index of in-order rank ``rank`` in a perfect BST.

    This is the paper's Eq. (1) specialised to a perfect tree: with
    ``t = rank + 1``, ``tz = trailing_zeros(t)``, the node depth is
    ``height - 1 - tz`` and the heap index is ``2^depth + (rank >> (tz+1))``.
    Branch-free, O(1), vectorises over ``rank``.
    """
    rank = jnp.asarray(rank, jnp.int32)
    t = rank + 1
    low = t & (-t)                                   # lowest set bit == 2^tz
    # log2 of an exact power of two is exact in f32 for the whole int32 range
    tz = jnp.int32(jnp.round(jnp.log2(low.astype(jnp.float32))))
    depth = jnp.int32(height) - 1 - tz
    return (jnp.int32(1) << depth) + (rank >> (tz + 1))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockManager:
    """Perfect-CBT block manager. All per-node arrays are heap-indexed."""

    hid: jax.Array      # int32[2^(h+1)] in-order rank stored at each node
    addr0: jax.Array    # int32[...] start address of primary block (-1 dummy)
    cap0: jax.Array     # int32[...] primary block capacity (slots incl. metadata)
    addr1: jax.Array    # int32[...] overflow block start (-1 = none)
    cap1: jax.Array     # int32[...] overflow block capacity
    card: jax.Array     # int32[...] current cardinality of the hyperedge
    present: jax.Array  # int32[...] 1 = live hyperedge
    deleted: jax.Array  # int32[...] 1 = freed block available for reuse
    avail: jax.Array    # int32[...] free blocks in subtree (incl. self)
    height: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_slots(self) -> int:
        return (1 << self.height) - 1

    @property
    def root_avail(self) -> jax.Array:
        return self.avail[1]


def build_manager(max_edges: int) -> BlockManager:
    """Parallel construction (paper Fig. 4): every node placed independently
    by the closed-form index map — a pure scatter, no sequential insert."""
    h = tree_height(max_edges)
    size = 1 << (h + 1)
    ranks = jnp.arange((1 << h) - 1, dtype=jnp.int32)
    idx = cbt_index(ranks, h)
    hid = jnp.zeros(size, jnp.int32).at[idx].set(ranks)
    zeros = jnp.zeros(size, jnp.int32)
    return BlockManager(
        hid=hid,
        addr0=jnp.full(size, -1, jnp.int32),
        cap0=zeros,
        addr1=jnp.full(size, -1, jnp.int32),
        cap1=zeros,
        card=zeros,
        present=zeros,
        deleted=zeros,
        avail=zeros,
        height=h,
    )


def search(mgr: BlockManager, queries: jax.Array) -> jax.Array:
    """Paper-faithful O(log|E|) BST descent for a batch of hyperedge ids.

    Retained for fidelity/benchmarks; `cbt_index` gives the same answer in
    O(1) (beyond-paper optimisation — see EXPERIMENTS.md §Perf-ESCHER).
    """
    h = mgr.height

    def one(q):
        def body(i, node):
            v = mgr.hid[node]
            go_right = v < q
            go_left = v > q
            nxt = jnp.where(go_right, 2 * node + 1, jnp.where(go_left, 2 * node, node))
            return jnp.minimum(nxt, mgr.hid.shape[0] - 1)

        return jax.lax.fori_loop(0, h, body, jnp.int32(1))

    return jax.vmap(one)(queries.astype(jnp.int32))


def _recompute_avail(mgr_avail, deleted, idx):
    """avail[idx] = deleted[idx] + avail[left] + avail[right] (vectorised)."""
    val = deleted[idx] + mgr_avail[2 * idx] + mgr_avail[2 * idx + 1]
    return mgr_avail.at[idx].set(val)


def propagate_avail(mgr: BlockManager, idxs: jax.Array, mask: jax.Array) -> BlockManager:
    """Level-by-level upward recompute of ``avail`` along the affected paths
    (paper Alg. 1 lines 13-19).  Duplicate parents recompute the same value,
    so scatter collisions are benign.  ``height + 1`` sweeps guarantee the
    deepest chain reaches the root with settled children.
    """
    safe = jnp.where(mask, idxs, 1).astype(jnp.int32)
    avail = _recompute_avail(mgr.avail, mgr.deleted, safe)

    def body(_, carry):
        avail, cur = carry
        cur = jnp.maximum(cur >> 1, 1)
        avail = _recompute_avail(avail, mgr.deleted, cur)
        return avail, cur

    avail, _ = jax.lax.fori_loop(0, mgr.height + 1, body, (avail, safe))
    return dataclasses.replace(mgr, avail=avail)


def mark_delete(mgr: BlockManager, ranks: jax.Array, mask: jax.Array) -> BlockManager:
    """Vertical delete (paper Alg. 1): mark nodes available, keep their block
    pointers for reuse, propagate ``avail`` to the root.  No rebalancing —
    the tree shape never changes (paper §III-B)."""
    idx = cbt_index(ranks, mgr.height)
    valid = mask & (mgr.present[idx] == 1)
    idxs = jnp.where(valid, idx, 0)  # slot 0 is unused scratch
    deleted = mgr.deleted.at[idxs].max(valid.astype(jnp.int32))
    present = mgr.present.at[idxs].min(jnp.where(valid, 0, 1).astype(jnp.int32))
    deleted = deleted.at[0].set(0)
    present = present.at[0].set(0)
    mgr = dataclasses.replace(mgr, deleted=deleted, present=present)
    return propagate_avail(mgr, idx, valid)


def find_kth_available(mgr: BlockManager, ks: jax.Array) -> jax.Array:
    """Paper Alg. 2: thread j descends from the root to the j-th available
    node, steered by the ``avail`` counters (in-order: left, self, right).
    Returns heap indices; invalid for k > root avail (caller masks)."""

    def one(k):
        def body(_, state):
            node, k, found = state
            left = 2 * node
            la = mgr.avail[left]
            in_left = (k <= la) & ~found
            here = (~in_left) & (k == la + mgr.deleted[node]) & (mgr.deleted[node] == 1) & ~found
            k_next = jnp.where(in_left | found | here, k, k - la - mgr.deleted[node])
            node_next = jnp.where(
                found | here, node, jnp.where(in_left, left, 2 * node + 1)
            )
            node_next = jnp.minimum(node_next, mgr.hid.shape[0] // 2 - 1)
            return node_next, k_next, found | here

        node, _, _ = jax.lax.fori_loop(
            0, mgr.height + 1, body, (jnp.int32(1), k.astype(jnp.int32), False)
        )
        return node

    return jax.vmap(one)(ks)


def recompute_avail(mgr: BlockManager) -> BlockManager:
    """Full bottom-up rebuild of the ``avail`` subtree counters from the
    ``deleted`` flags — one level-sized scatter per tree level.  Used after
    whole-tree surgery (``grow_manager``, ``core/elastic.py`` compaction)
    where path-local ``propagate_avail`` would not cover every node."""
    avail = jnp.zeros_like(mgr.avail)
    for d in range(mgr.height - 1, -1, -1):
        idx = jnp.arange(1 << d, 1 << (d + 1), dtype=jnp.int32)
        avail = _recompute_avail(avail, mgr.deleted, idx)
    return dataclasses.replace(mgr, avail=avail)


def grow_manager(mgr: BlockManager, levels: int = 1) -> BlockManager:
    """Grow the perfect BST by ``levels`` (rank space ×2 per level) with
    every existing rank preserved (core/elastic.py, DESIGN.md §8).

    The in-order rank of a node is the paper's hyperedge id, so growth must
    keep ranks stable while every *heap index* moves: rank ``r`` sits at
    ``cbt_index(r, h)`` in the old tree and ``cbt_index(r, h + levels)`` in
    the new one.  Migration is therefore one parallel gather/scatter per
    node array — no pointer walking, no data movement in ``A`` (block
    addresses are rank-independent).  The added ranks come up as dummy
    slots (``present=0``), exactly the state insertion Case 3 activates, so
    a grown tree is indistinguishable from one built at the larger size
    with the same contents.  ``avail`` is rebuilt bottom-up at the end."""
    if levels <= 0:
        return mgr
    h_new = mgr.height + levels
    new = build_manager((1 << h_new) - 1)
    assert new.height == h_new
    ranks = jnp.arange((1 << mgr.height) - 1, dtype=jnp.int32)
    src = cbt_index(ranks, mgr.height)
    dst = cbt_index(ranks, h_new)
    new = dataclasses.replace(
        new,
        addr0=new.addr0.at[dst].set(mgr.addr0[src]),
        cap0=new.cap0.at[dst].set(mgr.cap0[src]),
        addr1=new.addr1.at[dst].set(mgr.addr1[src]),
        cap1=new.cap1.at[dst].set(mgr.cap1[src]),
        card=new.card.at[dst].set(mgr.card[src]),
        present=new.present.at[dst].set(mgr.present[src]),
        deleted=new.deleted.at[dst].set(mgr.deleted[src]),
    )
    return recompute_avail(new)


def claim_nodes(mgr: BlockManager, idxs: jax.Array, mask: jax.Array) -> BlockManager:
    """Re-assign freed nodes to new hyperedges (insertion Case 1): clear the
    deleted flag, mark present, propagate ``avail`` down-counts."""
    safe = jnp.where(mask, idxs, 0).astype(jnp.int32)
    deleted = mgr.deleted.at[safe].min(jnp.where(mask, 0, 1).astype(jnp.int32))
    present = mgr.present.at[safe].max(mask.astype(jnp.int32))
    deleted = deleted.at[0].set(0)
    present = present.at[0].set(0)
    mgr = dataclasses.replace(mgr, deleted=deleted, present=present)
    return propagate_avail(mgr, jnp.where(mask, idxs, 1), mask)
