"""Hyperedge-based and temporal triad counting over a region (paper §III-C).

Enumeration: for every *adjacent* unordered pair (a, b) with a < b inside the
region, every third hyperedge c ∈ N(a) ∪ N(b) (deduplicated, region-
restricted) yields a connected-triple probe.  A closed triple (all three
pairs overlap) is generated exactly 3×, an open one exactly 2× — the final
histogram divides per class by that multiplicity, exactly.

Classification: the 7-region Venn emptiness code from cardinalities and
pair/triple intersection sizes (kernels/ops intersections), mapped through
the MoCHy 26-class tables (motifs.py).  Temporal mode instead time-orders
each triple and uses the ordered-pattern table plus the `t_max−t_min ≤ δ`
window (THyMe+ semantics).

Everything is fixed-shape: the caller bounds the region (`max_region`),
line-graph degree (`max_deg`), and the pair list is processed in chunks via
``lax.map`` to bound memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import motifs
from repro.core.hypergraph import Hypergraph, neighbors
from repro.core.store import EMPTY, dedupe_sorted, read_sorted
from repro.kernels import ops as kops

_CANON = jnp.asarray(motifs.CANON)
_CLASS_ID = jnp.asarray(motifs.CLASS_ID)
_CLASS_CLOSED = jnp.asarray(motifs.CLASS_CLOSED)
_TEMPORAL_ID = jnp.asarray(motifs.TEMPORAL_CLASS_ID)


def _member_bitmap(n_slots: int, ranks, mask):
    bm = jnp.zeros(n_slots + 1, jnp.int32)
    idx = jnp.where(mask, jnp.minimum(ranks, n_slots), n_slots)
    return bm.at[idx].set(1).at[n_slots].set(0)


def _restrict(vals, bitmap):
    safe = jnp.minimum(vals, bitmap.shape[0] - 1)
    ok = (vals != EMPTY) & (bitmap[safe] == 1)
    return jnp.where(ok, vals, EMPTY)


def _ordered_code(ca, cb, cc, iab, iac, ibc, iabc, ta, tb, tc):
    """Re-derive the 7-region code with (a,b,c) permuted into time order."""
    # sort keys: (time, tiebreak already encoded by caller adding rank eps)
    # compute permutation via pairwise comparisons
    a_first = (ta <= tb) & (ta <= tc)
    b_first = (~a_first) & (tb <= tc)
    # remaining two ordered
    def pick(fa, fb, fc):
        return jnp.where(a_first, fa, jnp.where(b_first, fb, fc))

    # For each of 3 choices of first, order the remaining two:
    # helper returning (cx, cy, cz, ixy, ixz, iyz) for given first element
    def order_rest(c1, c2, c3, i12, i13, i23, t2, t3):
        swap = t3 < t2
        cy = jnp.where(swap, c3, c2)
        cz = jnp.where(swap, c2, c3)
        ixy = jnp.where(swap, i13, i12)
        ixz = jnp.where(swap, i12, i13)
        iyz = i23
        return c1, cy, cz, ixy, ixz, iyz

    fa = order_rest(ca, cb, cc, iab, iac, ibc, tb, tc)
    fb = order_rest(cb, ca, cc, iab, ibc, iac, ta, tc)
    fc = order_rest(cc, ca, cb, iac, ibc, iab, ta, tb)
    cx, cy, cz, ixy, ixz, iyz = (pick(x, y, z) for x, y, z in zip(fa, fb, fc))
    return motifs.region_code(cx, cy, cz, ixy, ixz, iyz, iabc)


def probe_worklist(hg: Hypergraph, region_ranks, region_mask, *, max_deg: int):
    """Region-level probe work-list (DESIGN.md §3.2): the per-region
    neighbour rows plus the flattened ``(center, pair)`` list the chunk
    kernel consumes.  Shared lowering between the single-device driver
    (``count_triads``) and the sharded driver (``distributed/triads.py``),
    which partitions the flat pair list across mesh devices while the
    region-level arrays replicate.

    Returns ``(bitmap, nbrs, row_of, a, b, ok)`` where ``a/b/ok`` are the
    unpadded flat pair arrays of length ``R * max_deg``."""
    n_slots = hg.n_edge_slots
    bitmap = _member_bitmap(n_slots, region_ranks, region_mask)
    ranks = jnp.where(region_mask, region_ranks, 0)

    nbrs = neighbors(hg, ranks, max_deg)                  # [R, D]
    nbrs = _restrict(nbrs, bitmap)
    R, D = nbrs.shape
    # rank -> region row, so chunks reuse these rows instead of recomputing
    # the (v2h-expansion + dedupe-sort) neighbour derivation per pair (§E4)
    row_of = jnp.zeros(n_slots + 1, jnp.int32).at[
        jnp.where(region_mask, jnp.minimum(region_ranks, n_slots), n_slots)
    ].set(jnp.arange(R, dtype=jnp.int32)).at[n_slots].set(0)

    a_flat = jnp.repeat(ranks, D)
    b_flat = nbrs.reshape(-1)
    pair_ok = (
        jnp.repeat(region_mask, D)
        & (b_flat != EMPTY)
        & (b_flat > a_flat)
    )
    b_safe = jnp.where(pair_ok, b_flat, 0)
    return bitmap, nbrs, row_of, a_flat, b_safe, pair_ok


def pad_pairs(a, b, ok, multiple: int):
    """Pad the flat pair list to a multiple of ``multiple`` with masked-out
    entries (zero ranks, ok=False) so it splits evenly into chunks — and,
    in the sharded driver, evenly across devices."""
    P = a.shape[0]
    pad = (-P) % multiple
    if pad:
        a = jnp.concatenate([a, jnp.zeros(pad, jnp.int32)])
        b = jnp.concatenate([b, jnp.zeros(pad, jnp.int32)])
        ok = jnp.concatenate([ok, jnp.zeros(pad, bool)])
    return a, b, ok


def chunk_probe_stats(hg: Hypergraph, nbrs, row_of, bitmap, *, chunk: int,
                      backend: str):
    """Candidate expansion + ONE fused kernel launch for a probe chunk —
    the shared hot path under ``chunk_counter`` (histograms) and
    ``query/topk.py`` (top-k triplet scoring).  ``backend`` must already be
    resolved (``kops.resolve_backend``).

    Returns a function ``(a, b) -> (cand, (iab, iac, ibc, iabc),
    (ca, cb, cc))`` where ``cand`` is the deduplicated, region-restricted
    third-edge stack ``int32[chunk, K]`` and the stats follow
    ``kops.fused_triple_stats`` shapes."""
    n_slots = hg.n_edge_slots
    n_bits = hg.num_vertices

    def stats(a, b):
        na = nbrs[row_of[jnp.minimum(a, n_slots)]]        # precomputed rows
        nb = nbrs[row_of[jnp.minimum(b, n_slots)]]
        cand = jnp.concatenate([na, nb], axis=1)          # [chunk, 2D]
        cand = _restrict(cand, bitmap)
        cand = jnp.where((cand == a[:, None]) | (cand == b[:, None]), EMPTY, cand)
        cand = dedupe_sorted(cand)
        K = cand.shape[1]

        A = read_sorted(hg.h2v, a)                        # [chunk, c]
        B = read_sorted(hg.h2v, b)
        c_safe = jnp.where(cand == EMPTY, 0, cand)
        Cs = read_sorted(hg.h2v, c_safe.reshape(-1)).reshape(chunk, K, -1)

        from repro.core import blockmgr as bm
        card = hg.h2v.mgr.card
        hidx = lambda r: bm.cbt_index(r, hg.h2v.mgr.height)
        ca = card[hidx(a)]
        cb = card[hidx(b)]
        cc = card[hidx(c_safe)]

        # one fused launch: iab[chunk], iac/ibc/iabc[chunk, K]
        # (rows are read_sorted / dedupe_sorted output -> already sorted)
        iab, iac, ibc, iabc = kops.fused_triple_stats(
            A, B, Cs, backend=backend, n_bits=n_bits, assume_sorted=True)
        return cand, (iab, iac, ibc, iabc), (ca, cb, cc)

    return stats


def chunk_counter(
    hg: Hypergraph, nbrs, row_of, bitmap, t_by_rank, *,
    chunk: int, temporal: bool, window, backend,
):
    """Per-chunk probe kernel: ``(a, b, ok)`` int32[chunk] triples -> raw
    weighted class histogram (open triples ×3, closed ×2; divide the summed
    histogram by 6).  Factored out of ``count_triads`` so the sharded driver
    runs the identical kernel on its local slice of the pair list.

    The intersection hot spot is ONE fused kernel launch per chunk
    (``kops.fused_triple_stats`` via ``chunk_probe_stats``): the A/B/Cs
    tiles stream from HBM once and all four joint sizes (iab, iac, ibc,
    iabc) come out of the same pass — previously five launches (pair +
    membership + 2× stack + triple) each re-reading the rows.  ``backend``
    resolves here (bitset auto-selected for high-cardinality edges over
    dense universes — the ``kops.resolve_backend`` cost rule,
    DESIGN.md §2.5)."""
    n_out = motifs.NUM_TEMPORAL if temporal else motifs.NUM_CLASSES
    backend = kops.resolve_backend(
        backend, c=hg.h2v.max_card, n_bits=hg.num_vertices)
    stats = chunk_probe_stats(hg, nbrs, row_of, bitmap, chunk=chunk,
                              backend=backend)

    def one_chunk(args):
        a, b, ok = args
        cand, (iab, iac, ibc, iabc), (ca, cb, cc) = stats(a, b)
        c_safe = jnp.where(cand == EMPTY, 0, cand)

        valid = ok[:, None] & (cand != EMPTY)
        if temporal:
            ta = t_by_rank[a][:, None]
            tb = t_by_rank[b][:, None]
            tc = t_by_rank[c_safe]
            code = _ordered_code(
                ca[:, None], cb[:, None], cc,
                iab[:, None], iac, ibc, iabc, ta, tb, tc,
            )
            cls = _TEMPORAL_ID[code]
            if window is not None:
                tmax = jnp.maximum(jnp.maximum(ta, tb), tc)
                tmin = jnp.minimum(jnp.minimum(ta, tb), tc)
                valid &= (tmax - tmin) <= window
            closed = (
                (((code >> 3) & 1) | ((code >> 6) & 1))
                + (((code >> 4) & 1) | ((code >> 6) & 1))
                + (((code >> 5) & 1) | ((code >> 6) & 1))
            ) == 3
        else:
            code = motifs.region_code(
                ca[:, None], cb[:, None], cc, iab[:, None], iac, ibc, iabc
            )
            cls = _CLASS_ID[_CANON[code]]
            closed = _CLASS_CLOSED[jnp.maximum(cls, 0)] == 1

        valid &= cls >= 0
        # accumulate raw with multiplicity weight 2 (open) / 3 (closed) fixed
        # later: store open hits doubled*3 and closed *2 => common divisor 6
        w = jnp.where(closed, 2, 3)                        # 6 / multiplicity
        cls_safe = jnp.where(valid, cls, 0)
        hist = jnp.zeros(n_out, jnp.int32).at[cls_safe.reshape(-1)].add(
            jnp.where(valid, w, 0).reshape(-1)
        )
        return hist

    return one_chunk


@functools.partial(
    jax.jit,
    static_argnames=("max_deg", "chunk", "temporal", "backend"),
)
def count_triads(
    hg: Hypergraph,
    region_ranks: jax.Array,   # int32[R]
    region_mask: jax.Array,    # bool[R]
    *,
    max_deg: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,   # int32[n_edge_slots], by rank
    window: int | None = None,
    backend: str | None = None,
):
    """Histogram of triad classes among triples wholly inside the region.
    Returns int32[26] (or int32[NUM_TEMPORAL] in temporal mode)."""
    bitmap, nbrs, row_of, a_flat, b_safe, pair_ok = probe_worklist(
        hg, region_ranks, region_mask, max_deg=max_deg)
    a_flat, b_safe, pair_ok = pad_pairs(a_flat, b_safe, pair_ok, chunk)
    nchunk = a_flat.shape[0] // chunk

    t_by_rank = (times if times is not None
                 else jnp.zeros(hg.n_edge_slots, jnp.int32))
    one_chunk = chunk_counter(
        hg, nbrs, row_of, bitmap, t_by_rank,
        chunk=chunk, temporal=temporal, window=window, backend=backend)

    hists = jax.lax.map(
        one_chunk,
        (
            a_flat.reshape(nchunk, chunk),
            b_safe.reshape(nchunk, chunk),
            pair_ok.reshape(nchunk, chunk),
        ),
    )
    return jnp.sum(hists, axis=0) // 6


@functools.partial(jax.jit, static_argnames=("max_deg", "block"))
def neighbor_table(hg: Hypergraph, *, max_deg: int, block: int = 1024):
    """Line-graph rows for EVERY edge slot: ``int32[n_slots + 1, max_deg]``
    (row ``n_slots`` is the all-EMPTY sentinel; dead slots come out empty
    exactly as per-call ``neighbors`` would).  The query service builds
    this once per snapshot epoch and amortises it across all point-query
    traffic at that epoch (DESIGN.md §7): the per-call h2v∘v2h expansion +
    dedupe-sort is the dominant cost of a containing-triple work-list, and
    a table row is a gather.  Built in ``block``-sized strips via
    ``lax.map`` to bound the expansion's working set."""
    n_slots = hg.n_edge_slots
    n_pad = -(-n_slots // block) * block
    ranks = jnp.minimum(jnp.arange(n_pad, dtype=jnp.int32), n_slots - 1)
    rows = jax.lax.map(
        lambda r: neighbors(hg, r, max_deg), ranks.reshape(-1, block))
    rows = rows.reshape(n_pad, -1)[:n_slots]
    return jnp.concatenate(
        [rows, jnp.full((1, rows.shape[1]), EMPTY, jnp.int32)])


def containing_worklist(
    hg: Hypergraph, changed: jax.Array, mask: jax.Array, *,
    max_deg: int, dedupe_changed: bool = True, nbrs_table=None,
):
    """Flat probe work-list enumerating every triple that CONTAINS a query
    hyperedge — the shared lowering under ``count_triads_containing``
    (Alg. 3 deltas, ``dedupe_changed=True``: a triple containing several
    changed edges counts once, at its smallest changed member) and the
    batched point-query form ``count_triads_containing_each``
    (``dedupe_changed=False``: each query row q gets every triple containing
    ``changed[q]``, independently of the other rows).

    Enumeration per query edge c:
      (i)  {c, x, y} with x < y both ∈ N(c)      — c-centred or triangle;
      (ii) {c, x, y} with x ∈ N(c), y ∈ N(x),
           y ∉ N(c) ∪ {c}                        — x-centred open path.
    Cost O(M · deg²) per query — independent of the 2-hop region size.

    Returns ``(qi, cs, xs, ys, ok)`` flat int32 arrays of length
    ``M·(D(D−1)/2 + D²)`` where ``qi`` is the query row each probe belongs
    to; the sharded drivers split this list across devices.

    ``nbrs_table`` (from ``neighbor_table``, same ``max_deg``) replaces
    the per-occurrence neighbour derivation with gathers — bit-identical
    rows, and the work-list cost drops to the candidate comparisons."""
    n_slots = hg.n_edge_slots
    c_ranks = jnp.where(mask, changed, 0)
    if nbrs_table is None:
        look = None
        nb_c = neighbors(hg, c_ranks, max_deg)             # [M, D]
    else:
        assert nbrs_table.shape[1] == max_deg, (
            f"nbrs_table built for max_deg={nbrs_table.shape[1]}, "
            f"work-list asked for {max_deg}")
        look = lambda r: nbrs_table[jnp.minimum(r, n_slots)]
        nb_c = look(c_ranks)
    nb_c = jnp.where(mask[:, None], nb_c, EMPTY)
    M, D = nb_c.shape
    rows = jnp.arange(M, dtype=jnp.int32)

    # ---- case (i): unordered pairs inside N(c)
    iu, ju = jnp.triu_indices(D, k=1)
    xi = nb_c[:, iu]                                        # [M, P1]
    yi = nb_c[:, ju]
    ci = jnp.broadcast_to(c_ranks[:, None], xi.shape)
    qi_i = jnp.broadcast_to(rows[:, None], xi.shape)
    ok_i = (xi != EMPTY) & (yi != EMPTY)

    # ---- case (ii): x ∈ N(c), y ∈ N(x) \ (N(c) ∪ {c})
    x_flat = jnp.where(nb_c.reshape(-1) == EMPTY, 0, nb_c.reshape(-1))
    nb_x = (neighbors(hg, x_flat, max_deg) if look is None
            else look(x_flat)).reshape(M, D, D)             # [M, D, D]
    y2 = nb_x
    in_nc = jnp.any(
        (y2[:, :, :, None] == nb_c[:, None, None, :]) & (nb_c != EMPTY)[:, None, None, :],
        axis=-1)
    ok_ii = (
        (nb_c != EMPTY)[:, :, None]
        & (y2 != EMPTY)
        & ~in_nc
        & (y2 != c_ranks[:, None, None])
    )
    x2 = jnp.broadcast_to(nb_c[:, :, None], y2.shape)
    c2 = jnp.broadcast_to(c_ranks[:, None, None], y2.shape)
    qi_ii = jnp.broadcast_to(rows[:, None, None], y2.shape)

    qi = jnp.concatenate([qi_i.reshape(-1), qi_ii.reshape(-1)])
    cs = jnp.concatenate([ci.reshape(-1), c2.reshape(-1)])
    xs = jnp.concatenate([xi.reshape(-1), x2.reshape(-1)])
    ys = jnp.concatenate([yi.reshape(-1), y2.reshape(-1)])
    ok = jnp.concatenate([ok_i.reshape(-1), ok_ii.reshape(-1)])

    if dedupe_changed:
        # dedupe across changed members: count at the smallest changed member
        changed_map = jnp.zeros(n_slots + 1, jnp.int32)
        safe_changed = jnp.where(mask, jnp.minimum(changed, n_slots), n_slots)
        # store 1+rank to distinguish "not changed" (0)
        changed_map = changed_map.at[safe_changed].set(
            jnp.where(mask, changed + 1, 0)).at[n_slots].set(0)

        def chg_rank(v):
            return changed_map[
                jnp.minimum(jnp.where(v == EMPTY, n_slots, v), n_slots)] - 1
        for other in (xs, ys):
            r = chg_rank(other)
            ok &= ~((r >= 0) & (r < cs))

    xs = jnp.where(ok, xs, 0)
    ys = jnp.where(ok, ys, 0)
    return qi, cs, xs, ys, ok


def containing_classifier(hg: Hypergraph, t_by_rank, *, temporal: bool,
                          window, backend: str):
    """Per-chunk classifier for containing-triple probes: ``(c, x, y, ok)``
    int32[chunk] -> ``(cls, valid)``.  ONE fused kernel launch per chunk
    with a k=1 candidate stack (|A∩C| = |A∩A∩C| etc.).  ``backend`` must
    already be resolved; shared between the summed Alg. 3 delta path and
    the per-query scatter of ``count_triads_containing_each`` (plus its
    sharded twin)."""
    def classify(a, b, c, okc):
        A = read_sorted(hg.h2v, a)
        B = read_sorted(hg.h2v, b)
        C = read_sorted(hg.h2v, c)[:, None, :]
        from repro.core import blockmgr as bm
        card = hg.h2v.mgr.card
        hidx = lambda r: bm.cbt_index(r, hg.h2v.mgr.height)
        ca, cb, cc = card[hidx(a)], card[hidx(b)], card[hidx(c)]
        iab, iac, ibc, iabc = kops.fused_triple_stats(
            A, B, C, backend=backend, n_bits=hg.num_vertices,
            assume_sorted=True)
        iac, ibc, iabc = iac[:, 0], ibc[:, 0], iabc[:, 0]
        if temporal:
            ta, tb, tc = t_by_rank[a], t_by_rank[b], t_by_rank[c]
            code = _ordered_code(ca, cb, cc, iab, iac, ibc, iabc, ta, tb, tc)
            cls = _TEMPORAL_ID[code]
            valid = okc
            if window is not None:
                tmax = jnp.maximum(jnp.maximum(ta, tb), tc)
                tmin = jnp.minimum(jnp.minimum(ta, tb), tc)
                valid &= (tmax - tmin) <= window
        else:
            code = motifs.region_code(ca, cb, cc, iab, iac, ibc, iabc)
            cls = _CLASS_ID[_CANON[code]]
            valid = okc
        valid &= cls >= 0
        return cls, valid

    return classify


def containing_point_chunk(classify, n_queries: int, n_out: int):
    """Per-chunk kernel of the batched point query: classify the probes
    and scatter-add each hit into its query's histogram row.  Shared
    between ``count_triads_containing_each`` and its sharded twin (the
    bit-identical-parity contract rides on there being exactly one copy).
    ``(qi, c, x, y, ok)`` int32[chunk] -> int32[n_queries, n_out]."""
    def one_chunk(args):
        q, a, b, c, okc = args

        def live(_):
            cls, valid = classify(a, b, c, okc)
            cls_safe = jnp.where(valid, cls, 0)
            q_safe = jnp.where(valid, q, n_queries)   # oob -> drop
            return jnp.zeros((n_queries, n_out), jnp.int32).at[
                q_safe, cls_safe].add(valid.astype(jnp.int32), mode="drop")

        # probes are validity-compacted: all-masked chunks (the common case
        # at real degrees) skip the kernel at runtime
        return jax.lax.cond(
            jnp.any(okc), live,
            lambda _: jnp.zeros((n_queries, n_out), jnp.int32), None)

    return one_chunk


def pad_probes(arrays, ok, multiple: int):
    """Pad flat probe arrays (plus their mask) to a multiple of ``multiple``
    with masked-out zero entries."""
    P = ok.shape[0]
    pad = (-P) % multiple
    if pad:
        arrays = [jnp.concatenate([a, jnp.zeros(pad, a.dtype)])
                  for a in arrays]
        ok = jnp.concatenate([ok, jnp.zeros(pad, bool)])
    return arrays, ok


@functools.partial(
    jax.jit, static_argnames=("max_deg", "chunk", "temporal", "backend"))
def count_triads_containing(
    hg: Hypergraph,
    changed: jax.Array,      # int32[M] changed hyperedge ranks
    mask: jax.Array,         # bool[M]
    *,
    max_deg: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,
    window: int | None = None,
    backend: str | None = None,
):
    """Histogram of triads that CONTAIN ≥1 changed hyperedge (each triple
    counted once — §Perf iteration E2, and arguably the literal reading of
    the paper's Alg. 3 steps 2/5).  Enumeration and cost:
    ``containing_worklist``."""
    _, cs, xs, ys, ok = containing_worklist(
        hg, changed, mask, max_deg=max_deg, dedupe_changed=True)
    (cs, xs, ys), ok = pad_probes([cs, xs, ys], ok, chunk)
    nchunk = cs.shape[0] // chunk

    n_out = motifs.NUM_TEMPORAL if temporal else motifs.NUM_CLASSES
    t_by_rank = (times if times is not None
                 else jnp.zeros(hg.n_edge_slots, jnp.int32))
    kbackend = kops.resolve_backend(
        backend, c=hg.h2v.max_card, n_bits=hg.num_vertices)
    classify = containing_classifier(hg, t_by_rank, temporal=temporal,
                                     window=window, backend=kbackend)

    def one_chunk(args):
        a, b, c, okc = args
        cls, valid = classify(a, b, c, okc)
        cls_safe = jnp.where(valid, cls, 0)
        return jnp.zeros(n_out, jnp.int32).at[cls_safe].add(
            valid.astype(jnp.int32))

    hists = jax.lax.map(
        one_chunk,
        (cs.reshape(nchunk, chunk), xs.reshape(nchunk, chunk),
         ys.reshape(nchunk, chunk), ok.reshape(nchunk, chunk)),
    )
    return jnp.sum(hists, axis=0)


@functools.partial(
    jax.jit, static_argnames=("max_deg", "chunk", "temporal", "backend"))
def count_triads_containing_each(
    hg: Hypergraph,
    edges: jax.Array,        # int32[M] query hyperedge ranks
    mask: jax.Array,         # bool[M]
    *,
    max_deg: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,
    window: int | None = None,
    backend: str | None = None,
    nbrs_table: jax.Array | None = None,
):
    """Batched point queries: row q is the histogram of every triad
    containing ``edges[q]`` — bit-identical to
    ``count_triads_containing(hg, edges[q:q+1], …)`` per row, but the M
    probe work-lists concatenate into ONE padded kernel launch per chunk
    instead of M separate jit dispatches (the query-service hot path,
    DESIGN.md §7).  Duplicate query ranks each get their own full answer;
    a masked-off or dead rank yields a zero row.  ``nbrs_table`` (an
    epoch-level ``neighbor_table``) turns the work-list derivation into
    gathers — the engine amortises one table across all traffic at an
    epoch.

    Returns int32[M, 26] (or int32[M, NUM_TEMPORAL] in temporal mode)."""
    M = edges.shape[0]
    qi, cs, xs, ys, ok = containing_worklist(
        hg, edges, mask, max_deg=max_deg, dedupe_changed=False,
        nbrs_table=nbrs_table)
    # compact valid probes to the front (stable, so per-query order is
    # preserved): the fixed-shape D² enumeration is mostly masked padding
    # for real degrees, and the cond-guarded chunk below skips all-masked
    # chunks entirely — this is where batching beats M sequential launches
    # (fig20), not just in dispatch count
    order = jnp.argsort(~ok)
    qi, cs, xs, ys, ok = (a[order] for a in (qi, cs, xs, ys, ok))
    (qi, cs, xs, ys), ok = pad_probes([qi, cs, xs, ys], ok, chunk)
    nchunk = cs.shape[0] // chunk

    n_out = motifs.NUM_TEMPORAL if temporal else motifs.NUM_CLASSES
    t_by_rank = (times if times is not None
                 else jnp.zeros(hg.n_edge_slots, jnp.int32))
    kbackend = kops.resolve_backend(
        backend, c=hg.h2v.max_card, n_bits=hg.num_vertices)
    classify = containing_classifier(hg, t_by_rank, temporal=temporal,
                                     window=window, backend=kbackend)

    one_chunk = containing_point_chunk(classify, M, n_out)
    hists = jax.lax.map(
        one_chunk,
        (qi.reshape(nchunk, chunk), cs.reshape(nchunk, chunk),
         xs.reshape(nchunk, chunk), ys.reshape(nchunk, chunk),
         ok.reshape(nchunk, chunk)),
    )
    return jnp.where(mask[:, None], jnp.sum(hists, axis=0), 0)


def all_live_region(hg: Hypergraph, max_region: int):
    """(ranks, mask) covering every live hyperedge — full-recount region."""
    mgr = hg.h2v.mgr
    order = jnp.argsort(-mgr.present)
    idx = order[:max_region]
    return mgr.hid[idx], mgr.present[idx] == 1
