"""Hyperedge-based and temporal triad counting over a region (paper §III-C).

Enumeration: for every *adjacent* unordered pair (a, b) with a < b inside the
region, every third hyperedge c ∈ N(a) ∪ N(b) (deduplicated, region-
restricted) yields a connected-triple probe.  A closed triple (all three
pairs overlap) is generated exactly 3×, an open one exactly 2× — the final
histogram divides per class by that multiplicity, exactly.

Classification: the 7-region Venn emptiness code from cardinalities and
pair/triple intersection sizes (kernels/ops intersections), mapped through
the MoCHy 26-class tables (motifs.py).  Temporal mode instead time-orders
each triple and uses the ordered-pattern table plus the `t_max−t_min ≤ δ`
window (THyMe+ semantics).

Everything is fixed-shape: the caller bounds the region (`max_region`),
line-graph degree (`max_deg`), and the pair list is processed in chunks via
``lax.map`` to bound memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import motifs
from repro.core.hypergraph import Hypergraph, neighbors
from repro.core.store import EMPTY, dedupe_sorted, read_sorted
from repro.kernels import ops as kops

_CANON = jnp.asarray(motifs.CANON)
_CLASS_ID = jnp.asarray(motifs.CLASS_ID)
_CLASS_CLOSED = jnp.asarray(motifs.CLASS_CLOSED)
_TEMPORAL_ID = jnp.asarray(motifs.TEMPORAL_CLASS_ID)


def _member_bitmap(n_slots: int, ranks, mask):
    bm = jnp.zeros(n_slots + 1, jnp.int32)
    idx = jnp.where(mask, jnp.minimum(ranks, n_slots), n_slots)
    return bm.at[idx].set(1).at[n_slots].set(0)


def _restrict(vals, bitmap):
    safe = jnp.minimum(vals, bitmap.shape[0] - 1)
    ok = (vals != EMPTY) & (bitmap[safe] == 1)
    return jnp.where(ok, vals, EMPTY)


def _ordered_code(ca, cb, cc, iab, iac, ibc, iabc, ta, tb, tc):
    """Re-derive the 7-region code with (a,b,c) permuted into time order."""
    # sort keys: (time, tiebreak already encoded by caller adding rank eps)
    # compute permutation via pairwise comparisons
    a_first = (ta <= tb) & (ta <= tc)
    b_first = (~a_first) & (tb <= tc)
    # remaining two ordered
    def pick(fa, fb, fc):
        return jnp.where(a_first, fa, jnp.where(b_first, fb, fc))

    # For each of 3 choices of first, order the remaining two:
    # helper returning (cx, cy, cz, ixy, ixz, iyz) for given first element
    def order_rest(c1, c2, c3, i12, i13, i23, t2, t3):
        swap = t3 < t2
        cy = jnp.where(swap, c3, c2)
        cz = jnp.where(swap, c2, c3)
        ixy = jnp.where(swap, i13, i12)
        ixz = jnp.where(swap, i12, i13)
        iyz = i23
        return c1, cy, cz, ixy, ixz, iyz

    fa = order_rest(ca, cb, cc, iab, iac, ibc, tb, tc)
    fb = order_rest(cb, ca, cc, iab, ibc, iac, ta, tc)
    fc = order_rest(cc, ca, cb, iac, ibc, iab, ta, tb)
    cx, cy, cz, ixy, ixz, iyz = (pick(x, y, z) for x, y, z in zip(fa, fb, fc))
    return motifs.region_code(cx, cy, cz, ixy, ixz, iyz, iabc)


def probe_worklist(hg: Hypergraph, region_ranks, region_mask, *, max_deg: int):
    """Region-level probe work-list (DESIGN.md §3.2): the per-region
    neighbour rows plus the flattened ``(center, pair)`` list the chunk
    kernel consumes.  Shared lowering between the single-device driver
    (``count_triads``) and the sharded driver (``distributed/triads.py``),
    which partitions the flat pair list across mesh devices while the
    region-level arrays replicate.

    Returns ``(bitmap, nbrs, row_of, a, b, ok)`` where ``a/b/ok`` are the
    unpadded flat pair arrays of length ``R * max_deg``."""
    n_slots = hg.n_edge_slots
    bitmap = _member_bitmap(n_slots, region_ranks, region_mask)
    ranks = jnp.where(region_mask, region_ranks, 0)

    nbrs = neighbors(hg, ranks, max_deg)                  # [R, D]
    nbrs = _restrict(nbrs, bitmap)
    R, D = nbrs.shape
    # rank -> region row, so chunks reuse these rows instead of recomputing
    # the (v2h-expansion + dedupe-sort) neighbour derivation per pair (§E4)
    row_of = jnp.zeros(n_slots + 1, jnp.int32).at[
        jnp.where(region_mask, jnp.minimum(region_ranks, n_slots), n_slots)
    ].set(jnp.arange(R, dtype=jnp.int32)).at[n_slots].set(0)

    a_flat = jnp.repeat(ranks, D)
    b_flat = nbrs.reshape(-1)
    pair_ok = (
        jnp.repeat(region_mask, D)
        & (b_flat != EMPTY)
        & (b_flat > a_flat)
    )
    b_safe = jnp.where(pair_ok, b_flat, 0)
    return bitmap, nbrs, row_of, a_flat, b_safe, pair_ok


def pad_pairs(a, b, ok, multiple: int):
    """Pad the flat pair list to a multiple of ``multiple`` with masked-out
    entries (zero ranks, ok=False) so it splits evenly into chunks — and,
    in the sharded driver, evenly across devices."""
    P = a.shape[0]
    pad = (-P) % multiple
    if pad:
        a = jnp.concatenate([a, jnp.zeros(pad, jnp.int32)])
        b = jnp.concatenate([b, jnp.zeros(pad, jnp.int32)])
        ok = jnp.concatenate([ok, jnp.zeros(pad, bool)])
    return a, b, ok


def chunk_counter(
    hg: Hypergraph, nbrs, row_of, bitmap, t_by_rank, *,
    chunk: int, temporal: bool, window, backend,
):
    """Per-chunk probe kernel: ``(a, b, ok)`` int32[chunk] triples -> raw
    weighted class histogram (open triples ×3, closed ×2; divide the summed
    histogram by 6).  Factored out of ``count_triads`` so the sharded driver
    runs the identical kernel on its local slice of the pair list.

    The intersection hot spot is ONE fused kernel launch per chunk
    (``kops.fused_triple_stats``): the A/B/Cs tiles stream from HBM once and
    all four joint sizes (iab, iac, ibc, iabc) come out of the same pass —
    previously five launches (pair + membership + 2× stack + triple) each
    re-reading the rows.  ``backend`` resolves here (bitset auto-selected
    for high-cardinality edges over dense universes — the
    ``kops.resolve_backend`` cost rule, DESIGN.md §2.5)."""
    n_slots = hg.n_edge_slots
    n_out = motifs.NUM_TEMPORAL if temporal else motifs.NUM_CLASSES
    n_bits = hg.num_vertices
    backend = kops.resolve_backend(backend, c=hg.h2v.max_card, n_bits=n_bits)

    def one_chunk(args):
        a, b, ok = args
        na = nbrs[row_of[jnp.minimum(a, n_slots)]]        # precomputed rows
        nb = nbrs[row_of[jnp.minimum(b, n_slots)]]
        cand = jnp.concatenate([na, nb], axis=1)          # [chunk, 2D]
        cand = _restrict(cand, bitmap)
        cand = jnp.where((cand == a[:, None]) | (cand == b[:, None]), EMPTY, cand)
        cand = dedupe_sorted(cand)
        K = cand.shape[1]

        A = read_sorted(hg.h2v, a)                        # [chunk, c]
        B = read_sorted(hg.h2v, b)
        c_safe = jnp.where(cand == EMPTY, 0, cand)
        Cs = read_sorted(hg.h2v, c_safe.reshape(-1)).reshape(chunk, K, -1)

        from repro.core import blockmgr as bm
        card = hg.h2v.mgr.card
        hidx = lambda r: bm.cbt_index(r, hg.h2v.mgr.height)
        ca = card[hidx(a)]
        cb = card[hidx(b)]
        cc = card[hidx(c_safe)]

        # one fused launch: iab[chunk], iac/ibc/iabc[chunk, K]
        # (rows are read_sorted / dedupe_sorted output -> already sorted)
        iab, iac, ibc, iabc = kops.fused_triple_stats(
            A, B, Cs, backend=backend, n_bits=n_bits, assume_sorted=True)

        valid = ok[:, None] & (cand != EMPTY)
        if temporal:
            ta = t_by_rank[a][:, None]
            tb = t_by_rank[b][:, None]
            tc = t_by_rank[c_safe]
            code = _ordered_code(
                ca[:, None], cb[:, None], cc,
                iab[:, None], iac, ibc, iabc, ta, tb, tc,
            )
            cls = _TEMPORAL_ID[code]
            if window is not None:
                tmax = jnp.maximum(jnp.maximum(ta, tb), tc)
                tmin = jnp.minimum(jnp.minimum(ta, tb), tc)
                valid &= (tmax - tmin) <= window
            closed = (
                (((code >> 3) & 1) | ((code >> 6) & 1))
                + (((code >> 4) & 1) | ((code >> 6) & 1))
                + (((code >> 5) & 1) | ((code >> 6) & 1))
            ) == 3
        else:
            code = motifs.region_code(
                ca[:, None], cb[:, None], cc, iab[:, None], iac, ibc, iabc
            )
            cls = _CLASS_ID[_CANON[code]]
            closed = _CLASS_CLOSED[jnp.maximum(cls, 0)] == 1

        valid &= cls >= 0
        # accumulate raw with multiplicity weight 2 (open) / 3 (closed) fixed
        # later: store open hits doubled*3 and closed *2 => common divisor 6
        w = jnp.where(closed, 2, 3)                        # 6 / multiplicity
        cls_safe = jnp.where(valid, cls, 0)
        hist = jnp.zeros(n_out, jnp.int32).at[cls_safe.reshape(-1)].add(
            jnp.where(valid, w, 0).reshape(-1)
        )
        return hist

    return one_chunk


@functools.partial(
    jax.jit,
    static_argnames=("max_deg", "chunk", "temporal", "backend"),
)
def count_triads(
    hg: Hypergraph,
    region_ranks: jax.Array,   # int32[R]
    region_mask: jax.Array,    # bool[R]
    *,
    max_deg: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,   # int32[n_edge_slots], by rank
    window: int | None = None,
    backend: str | None = None,
):
    """Histogram of triad classes among triples wholly inside the region.
    Returns int32[26] (or int32[NUM_TEMPORAL] in temporal mode)."""
    bitmap, nbrs, row_of, a_flat, b_safe, pair_ok = probe_worklist(
        hg, region_ranks, region_mask, max_deg=max_deg)
    a_flat, b_safe, pair_ok = pad_pairs(a_flat, b_safe, pair_ok, chunk)
    nchunk = a_flat.shape[0] // chunk

    t_by_rank = (times if times is not None
                 else jnp.zeros(hg.n_edge_slots, jnp.int32))
    one_chunk = chunk_counter(
        hg, nbrs, row_of, bitmap, t_by_rank,
        chunk=chunk, temporal=temporal, window=window, backend=backend)

    hists = jax.lax.map(
        one_chunk,
        (
            a_flat.reshape(nchunk, chunk),
            b_safe.reshape(nchunk, chunk),
            pair_ok.reshape(nchunk, chunk),
        ),
    )
    return jnp.sum(hists, axis=0) // 6


@functools.partial(
    jax.jit, static_argnames=("max_deg", "chunk", "temporal", "backend"))
def count_triads_containing(
    hg: Hypergraph,
    changed: jax.Array,      # int32[M] changed hyperedge ranks
    mask: jax.Array,         # bool[M]
    *,
    max_deg: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,
    window: int | None = None,
    backend: str | None = None,
):
    """Histogram of triads that CONTAIN ≥1 changed hyperedge (each triple
    counted once — §Perf iteration E2, and arguably the literal reading of
    the paper's Alg. 3 steps 2/5).

    Enumeration per changed edge c (skipping triples whose smallest changed
    member is < c, so multi-changed triples count once):
      (i)  {c, x, y} with x < y both ∈ N(c)      — c-centred or triangle;
      (ii) {c, x, y} with x ∈ N(c), y ∈ N(x),
           y ∉ N(c) ∪ {c}                        — x-centred open path.
    Cost O(M · deg²) — independent of the 2-hop region size, which saturates
    on overlap-heavy hypergraphs.
    """
    n_slots = hg.n_edge_slots
    changed_map = jnp.zeros(n_slots + 1, jnp.int32)
    safe_changed = jnp.where(mask, jnp.minimum(changed, n_slots), n_slots)
    # store 1+rank to distinguish "not changed" (0)
    changed_map = changed_map.at[safe_changed].set(
        jnp.where(mask, changed + 1, 0)).at[n_slots].set(0)

    c_ranks = jnp.where(mask, changed, 0)
    nb_c = neighbors(hg, c_ranks, max_deg)                 # [M, D]
    nb_c = jnp.where(mask[:, None], nb_c, EMPTY)
    M, D = nb_c.shape

    # ---- case (i): unordered pairs inside N(c)
    iu, ju = jnp.triu_indices(D, k=1)
    xi = nb_c[:, iu]                                        # [M, P1]
    yi = nb_c[:, ju]
    ci = jnp.broadcast_to(c_ranks[:, None], xi.shape)
    ok_i = (xi != EMPTY) & (yi != EMPTY)

    # ---- case (ii): x ∈ N(c), y ∈ N(x) \ (N(c) ∪ {c})
    x_flat = jnp.where(nb_c.reshape(-1) == EMPTY, 0, nb_c.reshape(-1))
    nb_x = neighbors(hg, x_flat, max_deg).reshape(M, D, D)  # [M, D, D]
    y2 = nb_x
    in_nc = jnp.any(
        (y2[:, :, :, None] == nb_c[:, None, None, :]) & (nb_c != EMPTY)[:, None, None, :],
        axis=-1)
    ok_ii = (
        (nb_c != EMPTY)[:, :, None]
        & (y2 != EMPTY)
        & ~in_nc
        & (y2 != c_ranks[:, None, None])
    )
    x2 = jnp.broadcast_to(nb_c[:, :, None], y2.shape)
    c2 = jnp.broadcast_to(c_ranks[:, None, None], y2.shape)

    cs = jnp.concatenate([ci.reshape(-1), c2.reshape(-1)])
    xs = jnp.concatenate([xi.reshape(-1), x2.reshape(-1)])
    ys = jnp.concatenate([yi.reshape(-1), y2.reshape(-1)])
    ok = jnp.concatenate([ok_i.reshape(-1), ok_ii.reshape(-1)])

    # dedupe across changed members: count at the smallest changed member
    def chg_rank(v):
        return changed_map[jnp.minimum(jnp.where(v == EMPTY, n_slots, v), n_slots)] - 1
    for other in (xs, ys):
        r = chg_rank(other)
        ok &= ~((r >= 0) & (r < cs))

    xs = jnp.where(ok, xs, 0)
    ys = jnp.where(ok, ys, 0)

    P = cs.shape[0]
    pad = (-P) % chunk
    if pad:
        z = lambda a, f: jnp.concatenate([a, jnp.full(pad, f, a.dtype)])
        cs, xs, ys, ok = z(cs, 0), z(xs, 0), z(ys, 0), z(ok, False)
    nchunk = cs.shape[0] // chunk

    n_out = motifs.NUM_TEMPORAL if temporal else motifs.NUM_CLASSES
    t_by_rank = times if times is not None else jnp.zeros(n_slots, jnp.int32)
    kbackend = kops.resolve_backend(
        backend, c=hg.h2v.max_card, n_bits=hg.num_vertices)

    def one_chunk(args):
        a, b, c, okc = args
        A = read_sorted(hg.h2v, a)
        B = read_sorted(hg.h2v, b)
        C = read_sorted(hg.h2v, c)[:, None, :]
        from repro.core import blockmgr as bm
        card = hg.h2v.mgr.card
        hidx = lambda r: bm.cbt_index(r, hg.h2v.mgr.height)
        ca, cb, cc = card[hidx(a)], card[hidx(b)], card[hidx(c)]
        # one fused launch with a k=1 candidate stack replaces the former
        # pair + 3× triple sequence (|A∩C| = |A∩A∩C| etc.)
        iab, iac, ibc, iabc = kops.fused_triple_stats(
            A, B, C, backend=kbackend, n_bits=hg.num_vertices,
            assume_sorted=True)
        iac, ibc, iabc = iac[:, 0], ibc[:, 0], iabc[:, 0]
        if temporal:
            ta, tb, tc = t_by_rank[a], t_by_rank[b], t_by_rank[c]
            code = _ordered_code(ca, cb, cc, iab, iac, ibc, iabc, ta, tb, tc)
            cls = _TEMPORAL_ID[code]
            valid = okc
            if window is not None:
                tmax = jnp.maximum(jnp.maximum(ta, tb), tc)
                tmin = jnp.minimum(jnp.minimum(ta, tb), tc)
                valid &= (tmax - tmin) <= window
        else:
            code = motifs.region_code(ca, cb, cc, iab, iac, ibc, iabc)
            cls = _CLASS_ID[_CANON[code]]
            valid = okc
        valid &= cls >= 0
        cls_safe = jnp.where(valid, cls, 0)
        return jnp.zeros(n_out, jnp.int32).at[cls_safe].add(
            valid.astype(jnp.int32))

    hists = jax.lax.map(
        one_chunk,
        (cs.reshape(nchunk, chunk), xs.reshape(nchunk, chunk),
         ys.reshape(nchunk, chunk), ok.reshape(nchunk, chunk)),
    )
    return jnp.sum(hists, axis=0)


def all_live_region(hg: Hypergraph, max_region: int):
    """(ranks, mask) covering every live hyperedge — full-recount region."""
    mgr = hg.h2v.mgr
    order = jnp.argsort(-mgr.present)
    idx = order[:max_region]
    return mgr.hid[idx], mgr.present[idx] == 1
