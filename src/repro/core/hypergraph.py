"""Two-way dynamic hypergraph = a pair of EscherStores (paper §III, Table II).

The paper's single schema instantiates each mapping separately; "two-way
dynamics" means a vertical op on h2v induces horizontal ops on v2h and vice
versa.  This module owns that consistency:

  * hyperedge insertion  -> h2v vertical insert + v2h horizontal inserts
                            (the new hyperedge id joins each member vertex's
                            incident list)
  * hyperedge deletion   -> h2v vertical delete + v2h horizontal deletes
  * incident-vertex ops  -> h2v horizontal + v2h horizontal (dual)

Vertices are pre-registered ranks 0..num_vertices-1 in the v2h store (vertex
vertical ops are supported through the same code path as h2v vertical ops).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockmgr as bm
from repro.core import ops
from repro.core.store import EMPTY, EscherStore, init_store, read_dense, read_sorted


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Hypergraph:
    h2v: EscherStore
    v2h: EscherStore

    @property
    def n_edge_slots(self) -> int:
        return (1 << self.h2v.mgr.height) - 1

    @property
    def num_vertices(self) -> int:
        return (1 << self.v2h.mgr.height) - 1


def from_lists(
    edges: list[list[int]],
    *,
    num_vertices: int | None = None,
    max_edges: int | None = None,
    max_card: int | None = None,
    max_vdeg: int | None = None,
    granule: int = 32,
    slack: float = 2.0,
    min_capacity: int = 0,
) -> Hypergraph:
    """Host-side constructor from a Python list of vertex lists.

    ``min_capacity`` floors both stores' flattened-array capacity, which is
    otherwise derived from the *initial* edges only — required when starting
    from an empty or tiny hypergraph that a stream will grow
    (core/stream.py, DESIGN.md §5)."""
    n = len(edges)
    if num_vertices is None:
        num_vertices = 1 + max((max(e) for e in edges if e), default=0)
    if max_edges is None:
        max_edges = max(2 * n, 16)
    if max_card is None:
        max_card = max(max((len(e) for e in edges), default=1), 4)
    cards = np.array([len(e) for e in edges], np.int32)
    lists = np.full((n, max_card), EMPTY, np.int32)
    for i, e in enumerate(edges):
        lists[i, : len(e)] = sorted(e)
    cap_h = max(
        int(slack * max(int((((cards + 1 + granule - 1) // granule) * granule).sum()), granule)),
        min_capacity)
    h2v = init_store(jnp.asarray(lists), jnp.asarray(cards),
                     max_edges=max_edges, capacity=cap_h, granule=granule)

    vdeg = np.zeros(num_vertices, np.int64)
    for e in edges:
        for v in e:
            vdeg[v] += 1
    if max_vdeg is None:
        max_vdeg = max(int(vdeg.max(initial=1)) * 2, 8)
    vlists = np.full((num_vertices, max_vdeg), EMPTY, np.int32)
    fill = np.zeros(num_vertices, np.int64)
    for j, e in enumerate(edges):
        for v in e:
            vlists[v, fill[v]] = j
            fill[v] += 1
    vcards = fill.astype(np.int32)
    cap_v = max(
        int(slack * max(int((((vcards + 1 + granule - 1) // granule) * granule).sum()), granule)),
        min_capacity)
    v2h = init_store(jnp.asarray(vlists), jnp.asarray(vcards),
                     max_edges=num_vertices, capacity=cap_v, granule=granule)
    # the v2h tree is padded to 2^h - 1 slots and ``num_vertices`` reports
    # that full size — register the padding ranks as zero-capacity lists
    # (present, no block until first insert — the core/elastic.py idiom)
    # so every vertex id the property admits is a real incident list, not
    # a silently-invisible node
    n_slots = (1 << v2h.mgr.height) - 1
    if n_slots > num_vertices:
        pad = jnp.arange(num_vertices, n_slots, dtype=jnp.int32)
        idx = bm.cbt_index(pad, v2h.mgr.height)
        v2h = dataclasses.replace(
            v2h,
            mgr=dataclasses.replace(
                v2h.mgr, present=v2h.mgr.present.at[idx].set(1)),
            n_ranks=jnp.int32(n_slots))
    return Hypergraph(h2v=h2v, v2h=v2h)


def _dual_updates(lists, ranks, mask, is_insert_flag):
    """Flatten (hyperedge rank, member vertex) pairs into v2h horizontal ops."""
    m, cmax = lists.shape
    vids = lists.reshape(-1)
    hids = jnp.repeat(ranks, cmax)
    ok = jnp.repeat(mask, cmax) & (vids != EMPTY)
    ins = jnp.full(vids.shape, is_insert_flag, bool)
    # v2h: target list is the vertex, payload is the hyperedge id
    return vids, hids, ins, ok


def insert_hyperedges(hg: Hypergraph, lists, cards, mask) -> tuple[Hypergraph, jax.Array]:
    h2v, ranks = ops.insert_hyperedges(hg.h2v, lists, cards, mask)
    tgt, pay, ins, ok = _dual_updates(lists, jnp.maximum(ranks, 0), mask, True)
    v2h = ops.apply_vertex_updates(hg.v2h, tgt, pay, ins, ok)
    return Hypergraph(h2v=h2v, v2h=v2h), ranks


def delete_hyperedges(hg: Hypergraph, ranks, mask) -> Hypergraph:
    # capture member lists BEFORE the vertical delete
    lists = read_dense(hg.h2v, jnp.maximum(ranks, 0))
    h2v = ops.delete_hyperedges(hg.h2v, ranks, mask)
    tgt, pay, ins, ok = _dual_updates(lists, jnp.maximum(ranks, 0), mask, False)
    v2h = ops.apply_vertex_updates(hg.v2h, tgt, pay, ins, ok)
    return Hypergraph(h2v=h2v, v2h=v2h)


def apply_vertex_updates(hg: Hypergraph, hids, vids, is_insert, mask) -> Hypergraph:
    """Incident-vertex (horizontal) batch, mirrored into both mappings."""
    h2v = ops.apply_vertex_updates(hg.h2v, hids, vids, is_insert, mask)
    v2h = ops.apply_vertex_updates(hg.v2h, vids, hids, is_insert, mask)
    return Hypergraph(h2v=h2v, v2h=v2h)


def update_batch(hg: Hypergraph, del_ranks, del_mask, ins_lists, ins_cards, ins_mask):
    """One churn batch: deletions then insertions (paper Alg. 3 step 3)."""
    hg = delete_hyperedges(hg, del_ranks, del_mask)
    hg, new_ranks = insert_hyperedges(hg, ins_lists, ins_cards, ins_mask)
    return hg, new_ranks


# --------------------------------------------------------------------------
# Derived views
# --------------------------------------------------------------------------
def neighbors(hg: Hypergraph, ranks: jax.Array, max_deg: int) -> jax.Array:
    """Line-graph adjacency rows (h2h mapping, paper Fig. 2a): for each rank,
    the hyperedges sharing >=1 vertex, EMPTY-padded, deduplicated, self
    excluded.  Derived on demand from h2v ∘ v2h."""
    vlists = read_dense(hg.h2v, ranks)                       # [m, cmax]
    m, cmax = vlists.shape
    flat_v = jnp.minimum(vlists.reshape(-1), hg.num_vertices - 1)
    hlists = read_dense(hg.v2h, flat_v).reshape(m, cmax, -1)
    cand = jnp.where((vlists == EMPTY)[:, :, None], EMPTY, hlists).reshape(m, -1)
    cand = jnp.where(cand == ranks[:, None], EMPTY, cand)    # drop self
    cand = jnp.sort(cand, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((m, 1), bool), cand[:, 1:] == cand[:, :-1]], axis=1
    )
    cand = jnp.where(dup, EMPTY, cand)
    cand = jnp.sort(cand, axis=1)
    return cand[:, :max_deg]


def live_ranks_host(hg: Hypergraph) -> np.ndarray:
    """Host helper: ranks of live hyperedges (for tests/benchmarks)."""
    mgr = hg.h2v.mgr
    present = np.asarray(mgr.present)
    hid = np.asarray(mgr.hid)
    return np.sort(hid[np.nonzero(present)[0]])


def to_python(hg: Hypergraph) -> dict[int, set[int]]:
    """Host helper: materialise {rank: set(vertices)} for oracle comparison."""
    ranks = live_ranks_host(hg)
    if len(ranks) == 0:
        return {}
    rows = np.asarray(read_dense(hg.h2v, jnp.asarray(ranks)))
    out = {}
    for r, row in zip(ranks.tolist(), rows):
        out[r] = set(row[row != EMPTY].tolist())
    return out
