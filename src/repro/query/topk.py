"""Top-k hyperedge triplets by intersection weight (DESIGN.md §7).

The retrieval model of Niu & Aksoy's top-k hyperedge-triplet work: rank
unordered triples {a, b, c} of live hyperedges by a score of their joint
intersection structure — by default ``|a∩b∩c|`` — and return the k best.

Enumeration rides the existing probe lowering (``triads.probe_worklist`` +
``triads.chunk_probe_stats``, i.e. one fused ``kernels.ops.
fused_triple_stats`` launch per chunk, bitset backend included for
high-cardinality rows): every *connected* triple is generated as an
adjacent pair (a < b) plus a third edge c ∈ N(a) ∪ N(b).  A closed triple
is generated three times and an open one twice, so a canonicalisation mask
keeps exactly the generation whose (a, b) is the lexicographically
smallest adjacent pair of the triple:

    keep  iff  c > b  (a,b is the lex-min pair of a<b<c; always adjacent)
          or   a < c < b and |a∩c| = 0  ((a,c) precedes (a,b) but is not
                                         adjacent; c's own pair follows)

(a generation with c < a is never kept — (c, ·) pairs precede (a, b) and
at least one is adjacent since c came from N(a) ∪ N(b)).  Each connected
triple therefore survives exactly once — the brute-force oracle in
tests/test_query.py checks both the multiset and the order.

The k best are kept by a streaming merge: per chunk, candidates are
flattened, lexsorted by ``(-score, a, b, c)`` — ties broken
deterministically toward the smallest triple — and merged with the running
top-k through the same sort.  Scores must be non-negative; -1 is the
internal "no candidate" sentinel.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import triads as T
from repro.core.hypergraph import Hypergraph
from repro.core.store import EMPTY
from repro.kernels import ops as kops


def default_score(iab, iac, ibc, iabc, ca, cb, cc):
    """|a∩b∩c| — the hyperedge-triplet weight of the retrieval model."""
    del iab, iac, ibc, ca, cb, cc
    return iabc


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TopK:
    """``scores[k]`` descending; ``triples[k, 3]`` sorted ids a < b < c;
    ``valid`` masks real entries (fewer than k connected triples exist
    otherwise)."""
    scores: jax.Array   # int32[k]
    triples: jax.Array  # int32[k, 3]

    @property
    def valid(self) -> jax.Array:
        return self.scores >= 0


def merge_topk(scores, triples, k: int):
    """Deterministic top-k: lexsort by (-score, a, b, c), take k.  Also the
    cross-device merge of the sharded driver (all-gathered candidates run
    through the same sort, so sharded == single-device bit-identically)."""
    order = jnp.lexsort(
        (triples[:, 2], triples[:, 1], triples[:, 0], -scores))[:k]
    return scores[order], triples[order]


def topk_scan(stats, score, a, b, ok, *, k: int, chunk: int):
    """Streaming top-k over a (padded) flat pair list: per chunk, one fused
    stats launch, canonicalisation, then ``merge_topk`` against the running
    best.  The shared core under ``topk_triplets`` and its sharded twin
    (each device scans its local slice).  Returns ``(scores, triples)``."""
    nchunk = a.shape[0] // chunk

    def body(carry, args):
        best_s, best_t = carry
        a, b, ok = args
        cand, (iab, iac, ibc, iabc), (ca, cb, cc) = stats(a, b)
        s = score(iab[:, None], iac, ibc, iabc, ca[:, None], cb[:, None], cc)

        # canonical generation only (module docstring): each connected
        # triple scored exactly once
        keep = (cand > b[:, None]) | (
            (cand > a[:, None]) & (cand < b[:, None]) & (iac == 0))
        valid = ok[:, None] & (cand != EMPTY) & keep
        s = jnp.where(valid, s, -1)

        # triple sorted ascending: a < b always; place c
        c_ = jnp.where(valid, cand, EMPTY)
        a_ = jnp.broadcast_to(a[:, None], c_.shape)
        b_ = jnp.broadcast_to(b[:, None], c_.shape)
        u = jnp.minimum(a_, c_)
        w = jnp.maximum(b_, c_)
        v = jnp.where(c_ < a_, a_, jnp.where(c_ > b_, b_, c_))

        ss = jnp.concatenate([best_s, s.reshape(-1)])
        tt = jnp.concatenate(
            [best_t,
             jnp.stack([u.reshape(-1), v.reshape(-1), w.reshape(-1)], axis=1)])
        return merge_topk(ss, tt, k), None

    init = (jnp.full(k, -1, jnp.int32), jnp.full((k, 3), EMPTY, jnp.int32))
    (best_s, best_t), _ = jax.lax.scan(
        body, init,
        (a.reshape(nchunk, chunk), b.reshape(nchunk, chunk),
         ok.reshape(nchunk, chunk)))
    return best_s, best_t


@functools.partial(
    jax.jit, static_argnames=("k", "max_deg", "chunk", "backend", "score"))
def topk_triplets(
    hg: Hypergraph,
    region_ranks: jax.Array,   # int32[R] — candidate triples live inside
    region_mask: jax.Array,    # bool[R]
    *,
    k: int,
    max_deg: int,
    chunk: int = 1024,
    backend: str | None = None,
    score=None,                # static fn(iab, iac, ibc, iabc, ca, cb, cc)
) -> TopK:
    """The k highest-scoring connected hyperedge triples inside the region
    (use ``triads.all_live_region`` for the whole store).  ``score`` is a
    static traced function of the fused per-triple stats returning
    non-negative int32 — default ``|a∩b∩c|``.  Ties break toward the
    lexicographically smallest (a, b, c); results are bit-identical across
    backends and device meshes (the sharded twin all-gathers per-device
    candidates through the same merge)."""
    score = score or default_score
    backend = kops.resolve_backend(
        backend, c=hg.h2v.max_card, n_bits=hg.num_vertices)

    bitmap, nbrs, row_of, a, b, ok = T.probe_worklist(
        hg, region_ranks, region_mask, max_deg=max_deg)
    a, b, ok = T.pad_pairs(a, b, ok, chunk)
    stats = T.chunk_probe_stats(hg, nbrs, row_of, bitmap, chunk=chunk,
                                backend=backend)
    best_s, best_t = topk_scan(stats, score, a, b, ok, k=k, chunk=chunk)
    return TopK(scores=best_s, triples=best_t)
