"""Triad query service: snapshot-isolated batched point/top-k queries over
the live ESCHER store (DESIGN.md §7, docs/API.md).

    from repro import query

    snap = query.of_stream(state)          # epoch-stamped immutable view
    cache = query.QueryCache()
    answers = query.serve(
        snap,
        [query.triads_containing_edge(3), query.triads_at_vertex(7),
         query.topk_triplets(10), query.histogram()],
        max_deg=32, cache=cache)
"""
from repro.query.cache import QueryCache
from repro.query.engine import (
    Request,
    histogram,
    serve,
    topk_triplets,
    triads_at_vertex,
    triads_containing_edge,
)
from repro.query.snapshot import Snapshot, of_graph, of_stream
from repro.query.topk import TopK, default_score
from repro.query.topk import topk_triplets as run_topk

__all__ = [
    "QueryCache", "Request", "Snapshot", "TopK", "default_score",
    "histogram", "of_graph", "of_stream", "run_topk", "serve",
    "topk_triplets", "triads_at_vertex", "triads_containing_edge",
]
