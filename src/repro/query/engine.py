"""Batched heterogeneous query planner over a snapshot (DESIGN.md §7).

``serve`` takes a vector of mixed requests — per-edge point queries,
per-vertex point queries, top-k triplets, histogram — against one
``Snapshot`` and answers them with at most one batched lowering per
*kind*:

  * requests group by kind;
  * point-query groups first consult the epoch-keyed ``QueryCache``
    (cache.py) — hits are host lookups, no device work;
  * the misses of a group are deduplicated, padded to a power-of-two batch
    (bounding jit specialisations), and lowered through ONE call to the
    batched cores — ``triads.count_triads_containing_each`` /
    ``vertex_triads.count_vertex_triads_at`` — so N point queries cost one
    padded kernel launch per chunk instead of N jit dispatches;
  * ``topk`` runs the streaming top-k engine (topk.py) over the live
    region; ``histogram`` is O(1) off the snapshot's maintained counts.

With ``mesh=`` the batched point lowerings and the top-k scan run sharded
across the mesh's devices through ``distributed/triads.py`` —
bit-identical answers (``serve_queries`` there is the sharded front door).

Every answer is bit-identical to a fresh recount of the same quantity at
the snapshot's epoch, cache hits included — the coherence contract
validated in tests/test_query.py.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import motifs
from repro.core import triads as T
from repro.core import vertex_triads as VT
from repro.query import topk as TK
from repro.query.cache import QueryCache
from repro.query.snapshot import Snapshot

__all__ = [
    "Request", "triads_containing_edge", "triads_at_vertex",
    "topk_triplets", "histogram", "serve",
]


@dataclasses.dataclass(frozen=True)
class Request:
    """One query.  Build with the constructor helpers below."""
    kind: str      # "edge" | "vertex" | "topk" | "histogram"
    arg: int = 0   # edge rank / vertex id
    k: int = 0     # topk only


def triads_containing_edge(rank: int) -> Request:
    """Histogram of every triad containing hyperedge ``rank`` (a dead or
    unknown rank answers all-zeros)."""
    return Request("edge", arg=int(rank))


def triads_at_vertex(vid: int) -> Request:
    """(type1, type2, type3) of ``count_vertex_triads`` over the closed
    co-occurrence neighbourhood N[vid] — the vertex's local triad
    participation."""
    return Request("vertex", arg=int(vid))


def topk_triplets(k: int) -> Request:
    """The k highest-|a∩b∩c| connected hyperedge triples (pluggable score
    via ``serve(score=...)``; ties toward the smallest (a, b, c))."""
    return Request("topk", k=int(k))


def histogram() -> Request:
    """The snapshot's full triad histogram — O(1) from the maintained
    stream counts (recounted only for count-less graph snapshots)."""
    return Request("histogram")


def _pad_len(n: int, lo: int = 8) -> int:
    m = lo
    while m < n:
        m *= 2
    return m


def _edge_index(snap, max_deg, cache):
    """Epoch-level neighbour table for batched edge point queries: built
    once per (epoch, shape_key, max_deg) and parked on the cache, so every
    batch at this epoch pays gathers instead of the h2v∘v2h row
    derivation.  ``shape_key`` joins the key because elastic growth
    (core/elastic.py) changes the rank universe without advancing the
    epoch — a table built pre-growth has the wrong geometry."""
    if cache is not None and cache.edge_index is not None:
        epoch, shape, deg, table = cache.edge_index
        if (epoch == snap.epoch and shape == snap.shape_key
                and deg == max_deg):
            return table
    table = T.neighbor_table(snap.hg, max_deg=max_deg)
    if cache is not None:
        cache.edge_index = (snap.epoch, snap.shape_key, max_deg, table)
    return table


def _point_batch(snap, kind, idx_by_key, fn, cache, params):
    """Serve one point-query group: cache probe, dedupe, one batched
    lowering for the misses, fill + store.  ``idx_by_key`` maps query key
    (rank / vid) -> list of request positions; ``fn(keys, mask) ->
    int32[M, n_out]`` is the batched core.  ``params`` is the tuple of
    serve parameters the answer depends on (bounds, temporal family, …):
    it joins the cache key, so the same rank queried under different
    parameters never cross-serves.  Returns {position: answer}."""
    out = {}
    dirty_of = (snap.edge_dirty if kind == "edge" else snap.vertex_dirty)
    misses = []
    for key, positions in idx_by_key.items():
        val = None
        if cache is not None:
            val = cache.lookup(kind, (key, params), snap, dirty_of(key))
        if val is None:
            misses.append(key)
        else:
            for p in positions:
                out[p] = val
    if misses:
        M = _pad_len(len(misses))
        keys = np.zeros(M, np.int32)
        keys[: len(misses)] = misses
        mask = np.arange(M) < len(misses)
        answers = np.asarray(fn(jnp.asarray(keys), jnp.asarray(mask)))
        for j, key in enumerate(misses):
            # own the row and freeze it: the same object is handed to every
            # caller and future cache hit — a consumer mutating an answer
            # must error, not corrupt the cache
            val = answers[j].copy()
            val.setflags(write=False)
            if cache is not None:
                cache.store(kind, (key, params), snap.epoch, val)
            for p in idx_by_key[key]:
                out[p] = val
    return out


def serve(
    snap: Snapshot,
    requests: list[Request],
    *,
    max_deg: int = 32,
    max_nb: int = 32,
    max_region: int = 1023,
    chunk: int = 1024,
    temporal: bool = False,
    window: int | None = None,
    v_total: int | None = None,
    backend: str | None = None,
    score=None,
    mesh=None,
    cache: QueryCache | None = None,
):
    """Answer ``requests`` against ``snap``; returns one host result per
    request, in order (numpy histograms; ``topk.TopK`` with numpy leaves
    for topk).  Bounds (``max_deg``/``max_nb``/``max_region``/``chunk``)
    follow the counting-engine conventions (docs/API.md); ``temporal``
    classifies edge point queries with the snapshot's timestamps.
    ``cache`` enables the epoch-keyed point cache; ``mesh`` runs the
    batched lowerings sharded (distributed/triads.py)."""
    hg = snap.hg
    vt = v_total if v_total is not None else hg.num_vertices
    times = snap.times if temporal else None

    # the epoch-level neighbour index only pays off when it can be reused —
    # build it lazily (first edge miss) and only in cached (service) mode
    def table():
        return _edge_index(snap, max_deg, cache) if cache is not None else None

    if mesh is not None:
        from repro.distributed import triads as DT
        edge_fn = lambda keys, mask: DT.count_triads_containing_each_sharded(
            hg, keys, mask, mesh=mesh, max_deg=max_deg, chunk=chunk,
            temporal=temporal, times=times, window=window, backend=backend,
            nbrs_table=table())
        vertex_fn = lambda keys, mask: DT.count_vertex_triads_at_sharded(
            hg, keys, mask, vt, mesh=mesh, max_nb=max_nb, chunk=chunk,
            backend=backend)
        topk_fn = lambda reg, m, k: DT.topk_triplets_sharded(
            hg, reg, m, mesh=mesh, k=k, max_deg=max_deg, chunk=chunk,
            backend=backend, score=score)
    else:
        edge_fn = lambda keys, mask: T.count_triads_containing_each(
            hg, keys, mask, max_deg=max_deg, chunk=chunk, temporal=temporal,
            times=times, window=window, backend=backend, nbrs_table=table())
        vertex_fn = lambda keys, mask: VT.count_vertex_triads_at(
            hg, keys, mask, vt, max_nb=max_nb, chunk=chunk, backend=backend)
        topk_fn = lambda reg, m, k: TK.topk_triplets(
            hg, reg, m, k=k, max_deg=max_deg, chunk=chunk, backend=backend,
            score=score)

    n_out = motifs.NUM_TEMPORAL if temporal else motifs.NUM_CLASSES
    bounds = {"edge": snap.hg.n_edge_slots, "vertex": snap.hg.num_vertices}
    zeros = {"edge": np.zeros(n_out, np.int32),
             "vertex": np.zeros(3, np.int32)}
    for z in zeros.values():
        z.setflags(write=False)         # shared across result positions
    groups: dict[str, dict[int, list[int]]] = {"edge": {}, "vertex": {}}
    results: list = [None] * len(requests)
    for i, r in enumerate(requests):
        if r.kind in groups:
            # a key outside the store's address space answers all-zeros
            # directly — never hits the device (whose gathers clamp) or
            # the cache (whose dirty maps it would index out of bounds)
            if 0 <= r.arg < bounds[r.kind]:
                groups[r.kind].setdefault(r.arg, []).append(i)
            else:
                results[i] = zeros[r.kind]
        elif r.kind not in ("topk", "histogram"):
            raise ValueError(f"unknown query kind {r.kind!r}")

    # the cache key carries every parameter the answer depends on; chunk /
    # backend / mesh are excluded on purpose (bit-identical by contract).
    # The snapshot's shape_key rides along so entries cached before an
    # elastic growth (core/elastic.py) never serve after it — capacity is
    # part of the epoch key (DESIGN.md §8).  Compaction is excluded like
    # chunk/backend: it changes neither geometry nor answers by contract.
    edge_params = (snap.shape_key, max_deg, temporal,
                   window if temporal else None)
    vertex_params = (snap.shape_key, max_nb, int(vt))
    if groups["edge"]:
        results_by_pos = _point_batch(snap, "edge", groups["edge"],
                                      edge_fn, cache, edge_params)
        for p, v in results_by_pos.items():
            results[p] = v
    if groups["vertex"]:
        results_by_pos = _point_batch(snap, "vertex", groups["vertex"],
                                      vertex_fn, cache, vertex_params)
        for p, v in results_by_pos.items():
            results[p] = v

    # topk / histogram-recount enumerate the full live region: refuse a
    # bound that would silently truncate it (all_live_region keeps a
    # prefix with no saturation signal)
    if any(r.kind == "topk" or (r.kind == "histogram" and snap.counts is None)
           for r in requests):
        n_live = int(hg.h2v.n_live)
        if n_live > max_region:
            raise ValueError(
                f"max_region={max_region} < {n_live} live hyperedges: the "
                "top-k/histogram region would silently truncate — raise "
                "max_region (or serve histogram from a stream snapshot's "
                "maintained counts)")

    # topk: one engine run per distinct k (uncached — any dirty edge could
    # reorder the ranking, so there is no per-key invalidation to exploit)
    topk_cache: dict[int, TK.TopK] = {}
    for i, r in enumerate(requests):
        if r.kind == "topk":
            if r.k not in topk_cache:
                reg, m = T.all_live_region(hg, max_region)
                res = topk_fn(reg, m, r.k)
                topk_cache[r.k] = TK.TopK(
                    scores=np.asarray(res.scores),
                    triples=np.asarray(res.triples))
            results[i] = topk_cache[r.k]
        elif r.kind == "histogram":
            if snap.counts is not None:
                results[i] = np.asarray(snap.counts)
            else:
                reg, m = T.all_live_region(hg, max_region)
                results[i] = np.asarray(T.count_triads(
                    hg, reg, m, max_deg=max_deg, chunk=chunk,
                    temporal=temporal, times=times, window=window,
                    backend=backend))
    return results
