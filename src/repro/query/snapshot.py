"""Snapshot isolation for the triad query service (DESIGN.md §7).

A ``Snapshot`` is a cheap immutable view of the evolving store at a fixed
epoch.  Because every array in ``Hypergraph``/``StreamState`` is a jax
array — functionally updated, never mutated in place — a snapshot needs no
copy: it is a pytree of *references* plus the epoch counter.  The stream is
free to keep scanning; each ``_stream_step`` produces fresh arrays and the
snapshot keeps pointing at the old ones (double-buffering for free).

The one subtlety is racing an *in-flight* step: ``of_stream`` reads the
epoch scalar back to the host, which blocks until every dispatched step has
actually committed — so the captured ``(hg, counts, times)`` are always a
consistent post-step state, never a torn one.  The dirty-epoch maps are
pulled to host ints at the same time: the cache validity test
(``dirty_epoch[rank] <= cached_epoch`` — cache.py) then costs a numpy
lookup per query instead of a device round-trip.

Epoch semantics: ``StreamState.epoch`` counts applied scheduler steps;
static graphs snapshot at epoch 0 (``of_graph``).  Two snapshots of the
same stream are comparable (query answers cached at the earlier one can be
served at the later one if untouched by churn); snapshots of different
streams or graphs are not — use one ``QueryCache`` per stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hypergraph import Hypergraph


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Immutable epoch-stamped view of a hypergraph (plus, when taken from
    a stream, its maintained histogram and timestamps).

    ``dirty_epoch`` / ``v_dirty_epoch`` are **host** int32 arrays: per
    hyperedge rank / vertex id, the last epoch whose churn batch may have
    changed its triad participation (0 = never).  ``counts`` is whatever
    family the source stream maintained (26-class, temporal, or the
    3-vector) and backs the O(1) ``histogram`` query; ``None`` for plain
    graphs snapshotted without counts."""
    hg: Hypergraph
    epoch: int
    counts: jax.Array | None = None
    times: jax.Array | None = None
    dirty_epoch: np.ndarray | None = None
    v_dirty_epoch: np.ndarray | None = None

    @property
    def shape_key(self) -> tuple[int, int, int, int]:
        """The store geometry this snapshot was taken at: ``(h2v capacity,
        h2v tree height, v2h capacity, v2h tree height)``.  Elastic growth
        (core/elastic.py) preserves every rank and every answer, but it
        changes array shapes and the rank/vertex universes — so the engine
        folds this key into every cache key and into the epoch-level
        neighbour index key.  Epochs alone are not enough: growth happens
        *between* epochs (the segment re-runs from a checkpoint), so two
        snapshots at the same epoch can disagree on geometry."""
        return (self.hg.h2v.capacity, self.hg.h2v.mgr.height,
                self.hg.v2h.capacity, self.hg.v2h.mgr.height)

    def edge_dirty(self, rank: int) -> int:
        """Last epoch at which ``rank``'s triad participation may have
        changed (0 when tracking is absent — of_graph snapshots).  Keys
        outside the map answer the current epoch — never-cacheable — as
        defence in depth (the engine filters them before reaching here)."""
        if self.dirty_epoch is None:
            return 0
        if not 0 <= rank < len(self.dirty_epoch):
            return self.epoch
        return int(self.dirty_epoch[rank])

    def vertex_dirty(self, vid: int) -> int:
        if self.v_dirty_epoch is None:
            return 0
        if not 0 <= vid < len(self.v_dirty_epoch):
            return self.epoch
        return int(self.v_dirty_epoch[vid])


def of_stream(state) -> Snapshot:
    """Snapshot a ``core.stream.StreamState``.  Blocks until the last
    dispatched step has committed (reading ``epoch`` synchronises), then
    captures references — O(1) device work, two small host pulls for the
    dirty maps."""
    return Snapshot(
        hg=state.hg,
        epoch=int(state.epoch),
        counts=state.counts,
        times=state.times,
        dirty_epoch=np.asarray(state.dirty_epoch),
        v_dirty_epoch=np.asarray(state.v_dirty_epoch),
    )


def of_graph(hg: Hypergraph, *, counts=None, times=None,
             epoch: int = 0) -> Snapshot:
    """Snapshot a static ``Hypergraph`` (no stream): epoch 0, nothing ever
    dirty.  If you mutate ``hg`` through the store ops yourself, take a new
    snapshot with a larger ``epoch`` and a fresh cache — this constructor
    cannot observe out-of-band churn."""
    return Snapshot(hg=hg, epoch=epoch, counts=counts, times=times,
                    dirty_epoch=None, v_dirty_epoch=None)
