"""Epoch-keyed result cache for point queries (DESIGN.md §7).

Host-side by design: query results are small numpy vectors on their way to
users, and the validity test is a pure host computation against the
snapshot's dirty-epoch maps — no device traffic on a hit.

Invalidation rule (the whole cache in one line): an entry cached at epoch
``E`` is valid for a snapshot at epoch ``E' >= E`` iff the key was not
dirtied in ``(E, E']``, i.e. ``dirty_epoch[key] <= E``.  The dirty maps
are exactly the union affected regions that ``update.churn_step`` /
``vertex_churn_step`` compute for Alg. 3 — an edge outside every batch's
2-hop line-graph closure (vertex outside the 1-hop vertex closure) cannot
have gained or lost a triad, so serving its cached histogram is exact, not
approximate (validated in tests/test_query.py).

One cache serves one stream: epochs of different streams are unrelated.
Entries are never evicted by churn (staleness is detected lazily at
lookup); ``max_entries`` bounds memory with FIFO eviction.
"""
from __future__ import annotations

import collections


class QueryCache:
    """Per-edge / per-vertex point-query cache keyed by epoch.

    Keys are ``(kind, key)`` where the engine passes ``key = (rank|vid,
    params)`` — ``params`` being the serve parameters the answer depends
    on (max_deg / temporal family / window for edges, max_nb / v_total
    for vertices) plus the snapshot's ``shape_key`` (store capacities and
    tree heights), so the same rank under different parameters never
    cross-serves and entries cached before an elastic *growth*
    (core/elastic.py, DESIGN.md §8) never serve after it.  Compaction
    alone leaves ``shape_key`` unchanged on purpose: it is bit-exactly
    answer-preserving (tests/test_elastic.py), so serving across it is
    correct — that preservation is a contract compaction must keep, not
    something this key detects.  Values are
    whatever the engine stores (numpy histograms).  ``hits`` / ``misses``
    count lookups for observability (fig20 reports the hit rate)."""

    def __init__(self, max_entries: int = 1 << 16):
        self._d: collections.OrderedDict = collections.OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        # epoch-level neighbour index (engine.py):
        # (epoch, shape_key, max_deg, table).  One table serves every
        # batched edge point query at its epoch; rebuilt lazily when the
        # served snapshot's epoch — or, after elastic growth, its store
        # geometry — moves on.
        self.edge_index: tuple[int, tuple, int, object] | None = None

    def __len__(self) -> int:
        return len(self._d)

    def lookup(self, kind: str, key: int, snap, dirty: int):
        """Value cached for ``(kind, key)`` if still valid at ``snap``,
        else None.  ``dirty`` is the key's last-dirty epoch under ``snap``
        (``snap.edge_dirty(rank)`` / ``snap.vertex_dirty(vid)``)."""
        entry = self._d.get((kind, key))
        if entry is not None:
            epoch, value = entry
            # not from the future (a later snapshot's answer is not this
            # epoch's), and untouched since it was cached
            if epoch <= snap.epoch and dirty <= epoch:
                self.hits += 1
                return value
        self.misses += 1
        return None

    def store(self, kind: str, key: int, epoch: int, value) -> None:
        self._d[(kind, key)] = (epoch, value)
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)

    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0
