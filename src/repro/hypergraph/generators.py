"""Synthetic hypergraph generators echoing the paper's datasets (Table III).

Real datasets (Coauth/Tags/Threads/Orkut) are not redistributable inside
this container, so we generate synthetic hypergraphs with the same shape
statistics the paper reports: number of hyperedges, vertex pool, and the
cardinality regime (Tags: tiny cardinality 4; Coauth/Threads: small, heavy
tail; Orkut/Random: large cardinality).  Benchmarks scale these profiles
down by a common factor so they run on a CPU host; the *relative* contrasts
(incremental vs recount, cardinality effects) are preserved.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Profile:
    name: str
    n_edges: int
    n_vertices: int
    max_card: int
    card_dist: str  # "fixed" | "geom" | "zipf"
    mean_card: float = 4.0


# paper Table III, scaled for host execution by benchmarks (factor knob)
PROFILES = {
    "coauth": Profile("coauth", 2_599_087, 1_924_991, 280, "geom", 3.5),
    "tags": Profile("tags", 5_675_497, 49_998, 4, "fixed", 4.0),
    "orkut": Profile("orkut", 6_288_363, 3_072_441, 27_000, "zipf", 30.0),
    "threads": Profile("threads", 9_705_709, 2_675_955, 67, "geom", 2.5),
    "random": Profile("random", 15_000_000, 5_000_000, 10_000, "zipf", 20.0),
}


def sample_cards(p: Profile, n: int, rng: np.random.Generator, cap: int | None = None) -> np.ndarray:
    cap = min(cap or p.max_card, p.max_card)
    if p.card_dist == "fixed":
        c = np.full(n, int(p.mean_card))
    elif p.card_dist == "geom":
        c = 2 + rng.geometric(1.0 / max(p.mean_card - 1.0, 1.01), size=n)
    else:  # zipf-flavoured heavy tail
        c = 2 + (rng.pareto(1.5, size=n) * p.mean_card).astype(np.int64)
    return np.clip(c, 2, cap).astype(np.int32)


def random_hypergraph(
    n_edges: int,
    n_vertices: int,
    *,
    profile: str = "coauth",
    max_card: int | None = None,
    seed: int = 0,
    skew: float = 0.8,
) -> list[list[int]]:
    """Sample ``n_edges`` distinct hyperedges; vertex popularity is skewed
    (zipf, exponent ``skew``) so co-occurrence structure — and therefore
    triads — exists.  Lower skew keeps line-graph degree bounded (benchmark
    scaling sweeps)."""
    rng = np.random.default_rng(seed)
    p = PROFILES[profile]
    cards = sample_cards(p, n_edges, rng, cap=max_card)
    # skewed vertex popularity: triads need overlapping edges
    weights = 1.0 / np.arange(1, n_vertices + 1) ** skew
    weights /= weights.sum()
    out, seen = [], set()
    tries = 0
    while len(out) < n_edges and tries < 20 * n_edges:
        k = int(cards[len(out) % len(cards)])
        k = min(k, n_vertices)
        e = tuple(sorted(rng.choice(n_vertices, size=k, replace=False, p=weights).tolist()))
        tries += 1
        if e in seen:
            continue
        seen.add(e)
        out.append(list(e))
    return out


def churn_batch(
    live_ranks: np.ndarray,
    n_changes: int,
    delete_frac: float,
    n_vertices: int,
    max_card: int,
    *,
    profile: str = "coauth",
    seed: int = 0,
    card_cap: int | None = None,
) -> tuple[np.ndarray, list[list[int]]]:
    """A paper-style batch: x% deletions of random live edges + (1-x)%
    insertions of fresh random hyperedges."""
    rng = np.random.default_rng(seed)
    n_del = min(int(n_changes * delete_frac), len(live_ranks))
    n_ins = n_changes - n_del
    dels = rng.choice(live_ranks, size=n_del, replace=False).astype(np.int32)
    ins = random_hypergraph(n_ins, n_vertices, profile=profile,
                            max_card=card_cap or max_card, seed=seed + 1,
                            skew=0.3)
    return dels, ins


def event_stream(
    n_events: int,
    n_vertices: int,
    *,
    profile: str = "coauth",
    insert_frac: float = 0.7,
    seed: int = 0,
    max_card: int = 8,
    skew: float = 0.3,
    max_dt: int = 3,
) -> list[tuple]:
    """Timestamped hyperedge churn stream for core/stream.py: a mix of
    ``(t, "ins", members)`` inserts and ``(t, "del", ref)`` deletes, where
    ``ref`` indexes the earlier insert event being removed (producers never
    see store ranks).  Timestamps are *strictly increasing* with random gaps
    in [1, max_dt] — temporal triad classification time-orders each triple
    and requires distinct timestamps (the THyMe+ tiebreak contract, see
    triads._ordered_code); deletes target a uniformly random live insert."""
    rng = np.random.default_rng(seed)
    p = PROFILES[profile]
    weights = 1.0 / np.arange(1, n_vertices + 1) ** skew
    weights /= weights.sum()
    out: list[tuple] = []
    live: list[int] = []
    seen: set[tuple] = set()
    t = 0
    for i in range(n_events):
        t += int(rng.integers(1, max_dt + 1))
        if live and rng.random() >= insert_frac:
            j = int(rng.integers(0, len(live)))
            out.append((t, "del", live.pop(j)))
            continue
        e: tuple = ()
        for _ in range(20):  # fresh edge preferred; duplicates legal
            k = min(int(sample_cards(p, 1, rng, cap=max_card)[0]), n_vertices)
            e = tuple(sorted(rng.choice(n_vertices, size=k, replace=False,
                                        p=weights).tolist()))
            if e not in seen:
                break
        seen.add(e)
        out.append((t, "ins", list(e)))
        live.append(i)
    return out


def pack_lists(edges: list[list[int]], max_card: int) -> tuple[np.ndarray, np.ndarray]:
    EMPTY = np.iinfo(np.int32).max
    lists = np.full((len(edges), max_card), EMPTY, np.int32)
    cards = np.zeros(len(edges), np.int32)
    for i, e in enumerate(edges):
        e = e[:max_card]
        lists[i, : len(e)] = sorted(e)
        cards[i] = len(e)
    return lists, cards
