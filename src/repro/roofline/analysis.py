"""Three-term roofline from a compiled dry-run artifact (no hardware).

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis`` supplies FLOPs/bytes (whole-program, i.e. already the
global work; divide by chips).  Collective bytes are *not* in
cost_analysis: we parse the optimised HLO, sum the result sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
and multiply ops inside ``while`` loops by the loop trip count (scan-over-
layers puts FSDP all-gathers inside the loop body — missing the ×L would
understate the term by two orders of magnitude).  Trip counts are recovered
from the loop-condition constant; see ``_trip_count``.

Hardware constants: TPU v5e-class — 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI (assignment §ROOFLINE).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: int
    op_counts: dict


def _split_computations(hlo: str) -> dict:
    """computation name -> body text."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$", line)
        m2 = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(", line) if not m else None
        if (m or m2) and line.rstrip().endswith("{"):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = (m or m2).group(1)
            cur_lines = []
        elif line.strip() == "}":
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
                cur_lines = []
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _trip_count(cond_body: str) -> int:
    """Heuristic: scan conditions compare the induction var to a constant."""
    consts = [int(x) for x in re.findall(r"constant\((\d+)\)", cond_body)]
    return max(consts) if consts else 1


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)

    # per-computation direct collective bytes
    direct = {name: {} for name in comps}
    counts: dict = {}
    for name, body in comps.items():
        for line in body.splitlines():
            stripped = line.split("=", 1)
            if len(stripped) != 2:
                continue
            lhs, rhs = stripped
            opm = re.match(r"\s*%?[\w\.\-]*\s*", rhs)
            for kind in _COLLECTIVES:
                if re.match(rf"\s*{kind}[\.\s(]", rhs) or rhs.lstrip().startswith(kind):
                    b = _shape_bytes(lhs)
                    direct[name][kind] = direct[name].get(kind, 0) + b
                    counts[kind] = counts.get(kind, 0) + 1
                    break

    # calls: while loops multiply by trip count; other calls add once
    call_re = re.compile(
        r"(while|call|fusion|conditional)\(.*?\).*?"
        r"(?:body|to_apply|true_computation)=%?([\w\.\-]+)", )
    cond_re = re.compile(r"condition=%?([\w\.\-]+)")

    import functools

    @functools.lru_cache(maxsize=None)
    def total_of(name: str) -> dict:
        body = comps.get(name, "")
        acc = dict(direct.get(name, {}))
        for line in body.splitlines():
            m = call_re.search(line)
            if not m:
                continue
            op, callee = m.groups()
            sub = total_of(callee)
            mult = 1
            if op == "while":
                mc = cond_re.search(line)
                if mc:
                    mult = _trip_count(comps.get(mc.group(1), ""))
            for k, v in sub.items():
                acc[k] = acc.get(k, 0) + v * mult
        return acc

    entry = None
    for cand in ("main", "main.0"):
        if cand in comps:
            entry = cand
    if entry is None:  # fall back: the computation named like ENTRY
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
        entry = m.group(1) if m else max(comps, key=lambda n: len(comps[n]))
    by_kind = total_of(entry)
    return CollectiveStats(
        bytes_by_kind=by_kind,
        total_bytes=sum(by_kind.values()),
        op_counts=counts,
    )


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_collective: float
    model_flops: float
    useful_ratio: float
    chips: int = 256

    @property
    def dominant(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful model FLOPs vs what the chips could do in step_time."""
        if self.step_time_s == 0:
            return 0.0
        return self.model_flops / (self.step_time_s * PEAK_FLOPS * self.chips)


def roofline_from_hlo(hc, *, chips: int, model_flops: float) -> Roofline:
    """``hc``: HloCost from hlo_parse.parse_hlo — *per-device* values (the
    post-SPMD HLO carries local shapes), so the terms divide by per-chip
    rates directly; ``model_flops`` stays global and is normalised by
    ``chips`` in useful_ratio / roofline_fraction."""
    flops = float(hc.flops)
    byts = float(hc.bytes)
    coll = float(hc.total_collective)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll / LINK_BW
    useful = model_flops / (flops * chips) if flops else 0.0
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        flops=flops,
        bytes_hbm=byts,
        bytes_collective=coll,
        model_flops=model_flops,
        useful_ratio=useful,
        chips=chips,
    )
