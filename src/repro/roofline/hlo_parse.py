"""Optimised-HLO text parser for roofline extraction.

``compiled.cost_analysis()`` visits a ``while`` body once — a scan-over-
layers train step under-reports FLOPs/bytes/collectives by the loop trip
count (88× for mistral-large).  We therefore re-derive all three from the
HLO text:

  * per-computation symbol table (%name -> shape) from instruction results
    and computation parameters — optimised HLO does not inline operand
    shapes;
  * ``dot`` FLOPs = 2 × |result| × Π(lhs contracted dims), operand shape
    from the symbol table;
  * HBM bytes = result + operand sizes of top-level compute ops (post-
    fusion, elementwise chains live inside fusions, so fusion operands/
    results approximate HBM traffic);
  * collective bytes = result sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (async ``-start``
    counted once);
  * call-graph fold: ``while`` bodies multiply by the trip count — taken
    from the loop's ``known_trip_count`` backend config when present, else
    the largest constant in the loop condition.
"""
from __future__ import annotations

import dataclasses
import functools
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
    "all-gather-start", "all-reduce-start", "collective-permute-start",
}
_SKIP = {"all-gather-done", "all-reduce-done", "collective-permute-done",
         "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "iota", "after-all", "partition-id", "replica-id"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPCODE_RE = re.compile(r"\b([a-z][a-zA-Z\d_-]*)\(")


def _shapes(text: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of_shapes(shapes) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n, _ in shapes)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_ops: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)  # (mult_hint, callee)


def _split_computations(hlo: str) -> dict:
    comps, cur, buf = {}, None, []
    for line in hlo.splitlines():
        s = line.rstrip()
        if s.endswith("{") and "->" in s and "=" not in s.split("(")[0]:
            if cur is not None:
                comps[cur] = buf
            m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)", s)
            cur = m.group(1) if m else None
            buf = [s]  # keep header: parameter shapes live here
            continue
        if s.strip() == "}":
            if cur is not None:
                comps[cur] = buf
                cur, buf = None, []
            continue
        if cur is not None:
            buf.append(line)
    if cur is not None:
        comps[cur] = buf
    return comps


def _symbols(lines: list) -> dict:
    """%name -> shape list for results and parameters."""
    sym = {}
    header = lines[0] if lines else ""
    # header: `%comp (p0: f32[2,3], p1: (s32[], f32[4])) -> ... {`
    hdr = header.split("->")[0]
    for name, typ in re.findall(r"([\w\.\-]+)\s*:\s*(\([^\)]*\)|\S+)", hdr):
        sym[name] = _shapes(typ)
    for line in lines[1:]:
        if "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        m = re.search(r"%?([\w\.\-]+)\s*$", lhs.replace("ROOT", "").strip())
        if not m:
            continue
        name = m.group(1)
        om = _OPCODE_RE.search(rhs)
        result_txt = rhs[: om.start()] if om else rhs
        sym[name] = _shapes(result_txt)
        # gte: refine from operand's tuple element when index known
    return sym


def _operand_names(rhs: str, op_end: int) -> list:
    close = rhs.find(")", op_end)
    seg = rhs[op_end:close if close >= 0 else len(rhs)]
    return re.findall(r"%([\w\.\-]+)", seg)


def _parse_comp(lines: list) -> CompCost:
    c = CompCost()
    sym = _symbols(lines)

    def operand_bytes(rhs, op_end):
        return sum(_bytes_of_shapes(sym.get(n, [])) for n in _operand_names(rhs, op_end))

    for line in lines[1:]:
        if "=" not in line:
            continue
        _, rhs = line.split("=", 1)
        m = _OPCODE_RE.search(rhs)
        if not m:
            continue
        op = m.group(1)
        if op in _SKIP:
            continue
        result_shapes = _shapes(rhs[: m.start()])
        rbytes = _bytes_of_shapes(result_shapes)

        if op in _COLLECTIVES:
            kind = op.removesuffix("-start")
            c.coll_bytes[kind] = c.coll_bytes.get(kind, 0) + rbytes
            c.coll_ops[kind] = c.coll_ops.get(kind, 0) + 1
            c.bytes += rbytes + operand_bytes(rhs, m.end())
            continue
        if op == "dot":
            relems = sum(n for _, n, _ in result_shapes)
            ops_names = _operand_names(rhs, m.end())
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if ops_names and cm and relems:
                lhs_shape = sym.get(ops_names[0], [])
                if lhs_shape:
                    dims = lhs_shape[0][2]
                    contracted = 1
                    for ix in (int(i) for i in cm.group(1).split(",") if i):
                        if ix < len(dims):
                            contracted *= dims[ix]
                    c.flops += 2.0 * relems * contracted
            c.bytes += rbytes + operand_bytes(rhs, m.end())
            continue
        if op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", line)
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            trip = None
            tm = re.search(r'known_trip_count[^0-9]*(\d+)', line)
            if tm:
                trip = int(tm.group(1))
            if body:
                c.calls.append((("while", trip, cond.group(1) if cond else None),
                                body.group(1)))
            continue
        if op == "conditional":
            for grp in re.findall(r"_computation[s]?=\{?%?([\w\.\-]+)", line):
                c.calls.append((("cond", 1, None), grp))
            continue
        if op == "call":
            to = re.search(r"to_apply=%?([\w\.\-]+)", line)
            if to:
                c.calls.append((("call", 1, None), to.group(1)))
            continue
        # generic compute op (fusion, scatter, gather, sort, reduce, ...)
        c.bytes += rbytes + operand_bytes(rhs, m.end())
    return c


def _trip_count_from_cond(lines: list) -> int:
    consts = []
    for line in lines:
        consts += [int(x) for x in re.findall(r"constant\((\d+)\)", line)]
    return max(consts) if consts else 1


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    coll_bytes: dict
    coll_ops: dict

    @property
    def total_collective(self) -> float:
        return float(sum(self.coll_bytes.values()))


def parse_hlo(hlo: str) -> HloCost:
    comps = _split_computations(hlo)
    parsed = {name: _parse_comp(lines) for name, lines in comps.items()}

    @functools.lru_cache(maxsize=None)
    def fold(name: str) -> tuple:
        base = parsed.get(name)
        if base is None:
            return (0.0, 0.0, (), ())
        flops, byts = base.flops, base.bytes
        coll = dict(base.coll_bytes)
        ops = dict(base.coll_ops)
        for (kind, trip, cond), callee in base.calls:
            cf, cb, ccoll, cops = fold(callee)
            mult = 1
            if kind == "while":
                mult = trip if trip else _trip_count_from_cond(comps.get(cond, []))
            flops += cf * mult
            byts += cb * mult
            for k, v in ccoll:
                coll[k] = coll.get(k, 0) + v * mult
            for k, v in cops:
                ops[k] = ops.get(k, 0) + v * mult
        return (flops, byts, tuple(sorted(coll.items())), tuple(sorted(ops.items())))

    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    entry = m.group(1) if m else None
    if entry not in parsed:
        entry = max(parsed, key=lambda n: parsed[n].flops + parsed[n].bytes)
    f, b, coll, ops = fold(entry)
    return HloCost(flops=f, bytes=b, coll_bytes=dict(coll), coll_ops=dict(ops))
