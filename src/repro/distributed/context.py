"""Process-wide mesh registry.

`jax.lax.with_sharding_constraint`-style ambient mesh discovery is not
available for shard_map in this JAX version, so launchers register the mesh
they run under and distribution-aware modules (MoE EP dispatch) pick it up.
``None`` (tests, single-device smoke) selects the portable XLA path.
"""
from __future__ import annotations

from typing import Optional

import jax

_MESH: Optional[jax.sharding.Mesh] = None


def set_current_mesh(mesh: Optional[jax.sharding.Mesh]) -> None:
    global _MESH
    _MESH = mesh


def current_mesh() -> Optional[jax.sharding.Mesh]:
    return _MESH


def dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
