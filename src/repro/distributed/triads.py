"""Sharded triad engine: the (center, pair) probe work-list across a device
mesh (DESIGN.md §3.2).

The count kernels in ``core/triads.py`` / ``core/vertex_triads.py`` reduce a
flat probe work-list — ``(center, pair)`` hyperedge triples, or ``(u, v)``
vertex pairs — to a small integer histogram.  The work-list is the unit that
scales (it is O(region · deg²) while the store is O(edges)), so this module
shards exactly that:

  * the ESCHER store, the region-level neighbour rows, and the membership
    bitmap **replicate** on every device (``P()`` specs);
  * the flat pair list **shards** over every mesh axis (``P(axis_names)``),
    padded so it splits evenly;
  * each device runs the identical chunk kernel (``core.triads.
    chunk_counter`` / ``core.vertex_triads.chunk_triangles``) on its local
    slice and the partial histograms merge with a single ``psum`` — int32
    addition, so the result is **bit-identical** to the single-device path
    for any device count (validated in tests/test_distributed_triads.py).

Entry points mirror the single-device API with a ``mesh`` argument:
``count_triads_sharded`` (hyperedge + temporal families) and
``count_vertex_triads_sharded`` (incident-vertex family).  ``core/update.py``
threads them through the churn cores (``mesh=`` on ``churn_step`` /
``vertex_churn_step``) and ``core/stream.py`` through the scan driver, so
static counts, Alg. 3 maintenance, and streaming all scale across devices.

Testing recipe: the engine is backend-agnostic — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get an 8-way host
CPU mesh (``count_mesh(8)``) and compare against the single-device counts.
``lower_count_step`` lowers the same engine for the production TPU meshes
without allocating a store (``examples/dynamic_triads.py --dryrun``).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import motifs
from repro.core import triads as T
from repro.core import vertex_triads as VT
from repro.core.hypergraph import Hypergraph
from repro.kernels import ops as kops


# --------------------------------------------------------------- mesh helpers

def count_mesh(n_shards: int | None = None, *, devices=None) -> Mesh:
    """1-D counting mesh over ``n_shards`` devices (default: all available).

    The probe work-list has no tensor structure to exploit, so a flat
    ``("shard",)`` axis is the natural mesh for pure counting; the engine
    itself accepts *any* mesh and shards over all its axes (see
    ``shard_count``), which is how it rides the production LM meshes in
    ``lower_count_step``."""
    devices = list(devices if devices is not None else jax.devices())
    n = n_shards if n_shards is not None else len(devices)
    if not 1 <= n <= len(devices):
        raise ValueError(f"n_shards={n} outside 1..{len(devices)}")
    return Mesh(np.asarray(devices[:n]), ("shard",))


def shard_count(mesh: Mesh) -> int:
    """Number of work-list shards = total devices of the mesh (the pair list
    shards over *every* axis; the store replicates on every device)."""
    return int(math.prod(mesh.shape[a] for a in mesh.axis_names))


def _replicated(tree):
    return jax.tree_util.tree_map(lambda _: P(), tree)


def grow_replicated(
    hg: Hypergraph,
    *,
    mesh: Mesh,
    h2v_capacity: int | None = None,
    v2h_capacity: int | None = None,
    h2v_levels: int = 0,
    v2h_levels: int = 0,
    compact: bool = False,
) -> Hypergraph:
    """Grow (and optionally compact) the store on every device of ``mesh``
    in lockstep (core/elastic.py, DESIGN.md §8).

    The sharded engine replicates the store and shards only the probe
    work-list, so "growing all shards" means one host-coordinated
    ``grow_hypergraph`` followed by an explicit replicated placement: every
    device sees the identical post-growth arrays before the next
    ``shard_map`` launch, paying the broadcast once at growth time instead
    of per count call.  ``run_stream(auto_grow=True, mesh=...)`` reaches
    the same state implicitly (its host-side repair produces arrays the
    next jitted segment re-replicates); this is the explicit front door
    for callers managing their own store.  Sharded counts on the grown
    store stay bit-identical to single-device
    (tests/test_elastic.py::test_sharded_auto_grow_parity)."""
    from repro.core import elastic as EL

    if compact:
        hg = EL.compact_hypergraph(hg)
    hg = EL.grow_hypergraph(
        hg, h2v_capacity=h2v_capacity, v2h_capacity=v2h_capacity,
        h2v_levels=h2v_levels, v2h_levels=v2h_levels)
    return jax.device_put(hg, jax.sharding.NamedSharding(mesh, P()))


# ----------------------------------------------- hyperedge / temporal families

@functools.partial(
    jax.jit,
    static_argnames=("mesh", "max_deg", "chunk", "temporal", "window",
                     "backend"),
)
def count_triads_sharded(
    hg: Hypergraph,
    region_ranks: jax.Array,   # int32[R]
    region_mask: jax.Array,    # bool[R]
    *,
    mesh: Mesh,
    max_deg: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,   # int32[n_edge_slots], by rank
    window: int | None = None,
    backend: str | None = None,
):
    """Mesh-sharded twin of ``core.triads.count_triads`` — same histogram,
    bit-identical, with the pair work-list split across ``mesh``'s devices
    and one psum merging the partials.  ``mesh``/``window`` are static (the
    shard_map body closes over them)."""
    axes = tuple(mesh.axis_names)
    nshard = shard_count(mesh)
    # resolve once, outside the shard_map body, with the same (c, n_bits)
    # auto-selection inputs as the single-device path — every device must
    # lower the identical kernel, bitset included
    backend = kops.resolve_backend(
        backend, c=hg.h2v.max_card, n_bits=hg.num_vertices)

    bitmap, nbrs, row_of, a, b, ok = T.probe_worklist(
        hg, region_ranks, region_mask, max_deg=max_deg)
    a, b, ok = T.pad_pairs(a, b, ok, chunk * nshard)
    t_by_rank = (times if times is not None
                 else jnp.zeros(hg.n_edge_slots, jnp.int32))

    def local(hg, nbrs, row_of, bitmap, t_by_rank, a, b, ok):
        one_chunk = T.chunk_counter(
            hg, nbrs, row_of, bitmap, t_by_rank,
            chunk=chunk, temporal=temporal, window=window, backend=backend)
        nchunk = a.shape[0] // chunk
        hists = jax.lax.map(
            one_chunk,
            (a.reshape(nchunk, chunk), b.reshape(nchunk, chunk),
             ok.reshape(nchunk, chunk)))
        return jax.lax.psum(jnp.sum(hists, axis=0), axes)

    sharded = shard_map(
        local, mesh=mesh,
        in_specs=(_replicated(hg), P(), P(), P(), P(),
                  P(axes), P(axes), P(axes)),
        out_specs=P(),
        check_rep=False,
    )
    return sharded(hg, nbrs, row_of, bitmap, t_by_rank, a, b, ok) // 6


# -------------------------------------------------------- incident-vertex family

@functools.partial(
    jax.jit, static_argnames=("mesh", "max_nb", "chunk", "backend"))
def count_vertex_triads_sharded(
    hg: Hypergraph,
    region_vids: jax.Array,   # int32[R]
    region_mask: jax.Array,   # bool[R]
    v_total: jax.Array | int,
    *,
    mesh: Mesh,
    max_nb: int,
    chunk: int = 1024,
    backend: str | None = None,
) -> jax.Array:
    """Mesh-sharded twin of ``core.vertex_triads.count_vertex_triads``.

    Only the triangle enumeration shards — the wedge/edge closed-form terms
    are region-level scalars computed once on the replicated adjacency, and
    ``combine_counts`` assembles the final (type1, type2, type3) from the
    psum-merged triangle partials."""
    axes = tuple(mesh.axis_names)
    nshard = shard_count(mesh)
    # vertex-family universe is hyperedge *ranks* (v2h rows) — resolve with
    # that bound so the bitset auto-rule matches chunk_triangles
    backend = kops.resolve_backend(
        backend, c=hg.v2h.max_card, n_bits=hg.n_edge_slots)

    bitmap, u, v, ok, n_edges, wedges = VT.vertex_worklist(
        hg, region_vids, region_mask, max_nb=max_nb)
    u, v, ok = T.pad_pairs(u, v, ok, chunk * nshard)

    def local(hg, bitmap, u, v, ok):
        one_chunk = VT.chunk_triangles(
            hg, bitmap, max_nb=max_nb, chunk=chunk, backend=backend)
        nchunk = u.shape[0] // chunk
        per = jax.lax.map(
            one_chunk,
            (u.reshape(nchunk, chunk), v.reshape(nchunk, chunk),
             ok.reshape(nchunk, chunk)))
        return jax.lax.psum(jnp.sum(per, axis=0), axes)

    sharded = shard_map(
        local, mesh=mesh,
        in_specs=(_replicated(hg), P(), P(axes), P(axes), P(axes)),
        out_specs=P(),
        check_rep=False,
    )
    c3, covered = sharded(hg, bitmap, u, v, ok)
    return VT.combine_counts(c3, covered, n_edges, wedges, v_total)


# ------------------------------------------------------- query-service family

@functools.partial(
    jax.jit,
    static_argnames=("mesh", "max_deg", "chunk", "temporal", "window",
                     "backend"),
)
def count_triads_containing_each_sharded(
    hg: Hypergraph,
    edges: jax.Array,        # int32[M] query hyperedge ranks
    mask: jax.Array,         # bool[M]
    *,
    mesh: Mesh,
    max_deg: int,
    chunk: int = 1024,
    temporal: bool = False,
    times: jax.Array | None = None,
    window: int | None = None,
    backend: str | None = None,
    nbrs_table: jax.Array | None = None,
):
    """Mesh-sharded twin of ``core.triads.count_triads_containing_each``
    (the batched per-edge point query, DESIGN.md §7): the concatenated
    containing-triple probe list shards across the mesh and the per-query
    histograms merge with one psum — int32[M, n_out], bit-identical."""
    axes = tuple(mesh.axis_names)
    nshard = shard_count(mesh)
    backend = kops.resolve_backend(
        backend, c=hg.h2v.max_card, n_bits=hg.num_vertices)

    M = edges.shape[0]
    n_out = motifs.NUM_TEMPORAL if temporal else motifs.NUM_CLASSES
    qi, cs, xs, ys, ok = T.containing_worklist(
        hg, edges, mask, max_deg=max_deg, dedupe_changed=False,
        nbrs_table=nbrs_table)
    # validity-compact as in the single-device path, then deal the sorted
    # probes round-robin across shards: each shard gets an equal share of
    # the live probes (front-loaded on its local slice, so the cond-skip
    # still fires on its masked tail) instead of shard 0 getting them all
    order = jnp.argsort(~ok)
    qi, cs, xs, ys, ok = (a[order] for a in (qi, cs, xs, ys, ok))
    (qi, cs, xs, ys), ok = T.pad_probes([qi, cs, xs, ys], ok, chunk * nshard)
    deal = lambda a: a.reshape(-1, nshard).T.reshape(-1)
    qi, cs, xs, ys, ok = (deal(a) for a in (qi, cs, xs, ys, ok))
    t_by_rank = (times if times is not None
                 else jnp.zeros(hg.n_edge_slots, jnp.int32))

    def local(hg, t_by_rank, qi, cs, xs, ys, ok):
        classify = T.containing_classifier(
            hg, t_by_rank, temporal=temporal, window=window, backend=backend)
        nchunk = qi.shape[0] // chunk
        one_chunk = T.containing_point_chunk(classify, M, n_out)
        hists = jax.lax.map(
            one_chunk,
            (qi.reshape(nchunk, chunk), cs.reshape(nchunk, chunk),
             xs.reshape(nchunk, chunk), ys.reshape(nchunk, chunk),
             ok.reshape(nchunk, chunk)))
        return jax.lax.psum(jnp.sum(hists, axis=0), axes)

    sharded = shard_map(
        local, mesh=mesh,
        in_specs=(_replicated(hg), P(),
                  P(axes), P(axes), P(axes), P(axes), P(axes)),
        out_specs=P(),
        check_rep=False,
    )
    out = sharded(hg, t_by_rank, qi, cs, xs, ys, ok)
    return jnp.where(mask[:, None], out, 0)


@functools.partial(
    jax.jit, static_argnames=("mesh", "max_nb", "chunk", "backend"))
def count_vertex_triads_at_sharded(
    hg: Hypergraph,
    vids: jax.Array,         # int32[M] query vertex ids
    mask: jax.Array,         # bool[M]
    v_total: jax.Array | int,
    *,
    mesh: Mesh,
    max_nb: int,
    chunk: int = 1024,
    backend: str | None = None,
) -> jax.Array:
    """Mesh-sharded twin of ``core.vertex_triads.count_vertex_triads_at``:
    the batched point pair list shards; per-query (triangles, covered)
    partials psum-merge; the closed-form assembly runs replicated —
    int32[M, 3], bit-identical."""
    axes = tuple(mesh.axis_names)
    nshard = shard_count(mesh)
    backend = kops.resolve_backend(
        backend, c=hg.v2h.max_card, n_bits=hg.n_edge_slots)

    M = vids.shape[0]
    bitmaps, qi, u, v, ok, n_edges, wedges = VT.point_worklists(
        hg, vids, mask, max_nb=max_nb)
    (qi, u, v), ok = T.pad_probes([qi, u, v], ok, chunk * nshard)

    def local(hg, bitmaps, qi, u, v, ok):
        one_chunk = VT.point_chunk_triangles(
            hg, bitmaps, max_nb=max_nb, chunk=chunk, backend=backend,
            n_queries=M)
        nchunk = qi.shape[0] // chunk
        per = jax.lax.map(
            one_chunk,
            (qi.reshape(nchunk, chunk), u.reshape(nchunk, chunk),
             v.reshape(nchunk, chunk), ok.reshape(nchunk, chunk)))
        return jax.lax.psum(jnp.sum(per, axis=0), axes)

    sharded = shard_map(
        local, mesh=mesh,
        in_specs=(_replicated(hg), P(), P(axes), P(axes), P(axes), P(axes)),
        out_specs=P(),
        check_rep=False,
    )
    c3, covered = sharded(hg, bitmaps, qi, u, v, ok).T
    hist = jax.vmap(VT.combine_counts, in_axes=(0, 0, 0, 0, None))(
        c3, covered, n_edges, wedges, v_total)
    return jnp.where(mask[:, None], hist, 0)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "max_deg", "chunk", "backend", "score"))
def topk_triplets_sharded(
    hg: Hypergraph,
    region_ranks: jax.Array,
    region_mask: jax.Array,
    *,
    mesh: Mesh,
    k: int,
    max_deg: int,
    chunk: int = 1024,
    backend: str | None = None,
    score=None,
):
    """Mesh-sharded twin of ``query.topk.topk_triplets``: each device scans
    its slice of the pair work-list keeping a local top-k, the per-device
    candidates all-gather, and the same deterministic merge
    (``merge_topk``) picks the global k — bit-identical to single-device
    for any device count (a triple's canonical generation lives on exactly
    one shard, so candidates never double-count)."""
    from repro.query import topk as TK

    score = score or TK.default_score
    axes = tuple(mesh.axis_names)
    nshard = shard_count(mesh)
    backend = kops.resolve_backend(
        backend, c=hg.h2v.max_card, n_bits=hg.num_vertices)

    bitmap, nbrs, row_of, a, b, ok = T.probe_worklist(
        hg, region_ranks, region_mask, max_deg=max_deg)
    a, b, ok = T.pad_pairs(a, b, ok, chunk * nshard)

    def local(hg, nbrs, row_of, bitmap, a, b, ok):
        stats = T.chunk_probe_stats(hg, nbrs, row_of, bitmap, chunk=chunk,
                                    backend=backend)
        best_s, best_t = TK.topk_scan(stats, score, a, b, ok, k=k,
                                      chunk=chunk)
        gs = jax.lax.all_gather(best_s, axes, tiled=True)
        gt = jax.lax.all_gather(best_t, axes, tiled=True)
        return TK.merge_topk(gs, gt, k)

    sharded = shard_map(
        local, mesh=mesh,
        in_specs=(_replicated(hg), P(), P(), P(),
                  P(axes), P(axes), P(axes)),
        out_specs=(P(), P()),
        check_rep=False,
    )
    best_s, best_t = sharded(hg, nbrs, row_of, bitmap, a, b, ok)
    return TK.TopK(scores=best_s, triples=best_t)


def serve_queries(snap, requests, *, mesh: Mesh, **kw):
    """Sharded front door of the query service: exactly
    ``query.engine.serve`` with every batched lowering — per-edge and
    per-vertex point batches, top-k — running across ``mesh``'s devices
    (the histogram query stays O(1) off the snapshot).  Answers are
    bit-identical to the single-device ``serve``
    (tests/test_query.py::test_serve_sharded_parity)."""
    from repro.query import engine as QE

    return QE.serve(snap, requests, mesh=mesh, **kw)


# ------------------------------------------------- production-mesh dry lowering

def abstract_hypergraph(
    n_edges: int, *, max_card: int = 32, granule: int = 32,
) -> Hypergraph:
    """``ShapeDtypeStruct`` skeleton of a production-sized two-way store —
    for lowering/compiling the engine without allocating anything
    (``lower_count_step``; previously private to the example's dry-run)."""
    import repro.core.blockmgr as bm
    import repro.core.store as ST

    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)

    def abstract_store(n_lists: int, mc: int) -> ST.EscherStore:
        h = bm.tree_height(n_lists)
        size = 1 << (h + 1)
        mgr = bm.BlockManager(
            hid=i32(size), addr0=i32(size), cap0=i32(size),
            addr1=i32(size), cap1=i32(size), card=i32(size),
            present=i32(size), deleted=i32(size), avail=i32(size), height=h)
        return ST.EscherStore(A=i32(n_edges * 64), mgr=mgr, free_ptr=i32(),
                              n_ranks=i32(), error=i32(), granule=granule,
                              max_card=mc)

    return Hypergraph(h2v=abstract_store(n_edges, max_card),
                      v2h=abstract_store(n_edges // 2, 2 * max_card))


def lower_count_step(
    mesh: Mesh,
    *,
    n_edges: int = 1_000_000,
    region: int = 1 << 16,
    max_deg: int = 32,
    chunk: int = 4096,
    backend: str | None = None,
):
    """Lower + compile the sharded static count for ``mesh`` on an abstract
    store.  Returns ``(compiled, has_all_reduce)`` — the collective must be
    present in the HLO or the merge was optimised away (the dry-run asserts
    it).  This is the one distributed lowering; the example's ``--dryrun``
    is a thin wrapper over it."""
    hg = abstract_hypergraph(n_edges)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)

    def step(hg, ranks, mask):
        return count_triads_sharded(
            hg, ranks, mask, mesh=mesh, max_deg=max_deg, chunk=chunk,
            backend=backend)

    lowered = jax.jit(step).lower(
        hg, i32(region), jax.ShapeDtypeStruct((region,), jnp.bool_))
    compiled = lowered.compile()
    return compiled, ("all-reduce" in compiled.as_text())
