"""Int8 gradient compression with error feedback (1000-node posture).

At multi-pod scale the gradient all-reduce over the "pod" axis crosses DCN;
quantising gradients to int8 with a per-tensor scale cuts that traffic 4×
(vs f32 accumulation).  Error feedback keeps the quantisation *unbiased over
time*: the residual of each step is added back before the next quantisation,
so SGD-style convergence guarantees survive (Karimireddy et al., 2019).

The round-trip (quantise → dequantise) is applied to the *accumulated*
gradient; under jit + SPMD the all-reduce then operates on the int8-scaled
values.  tests/test_compression.py checks the error-feedback invariant and
end-to-end convergence parity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant_one(g: jax.Array, ef: jax.Array):
    g32 = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g32 - deq  # (compressed gradient, new error residual)


def compress_decompress(grads: dict, ef: dict | None):
    if ef is None:
        ef = jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out, new_ef = {}, {}
    for k in grads:
        out[k], new_ef[k] = _quant_one(grads[k], ef[k])
    return out, new_ef
