"""Logical-axis → mesh sharding rules (DP / TP / EP / SP / FSDP).

Two parameter modes:
  * ``tp``       — Megatron tensor parallelism on the "model" axis only;
                   params replicated across "data"/"pod".  Right for small
                   archs where params/16 fits HBM.
  * ``fsdp_tp``  — 2-D sharding: the d_model ("embed") dimension shards over
                   "data" (FSDP-style, XLA all-gathers weights per layer) and
                   the head/ffn/vocab/expert dimension over "model".  Needed
                   for ≥90B archs on 16 GB v5e chips (DESIGN.md §3).

Activations: batch over ("pod", "data"); decode KV caches shard sequence
over "model" and batch over "data" (SP for the 500k cell).  MoE experts ride
the "model" axis (EP) in both modes.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L


def _dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_rules(mode: str, mesh: Mesh) -> dict:
    fsdp = "data" if mode == "fsdp_tp" else None
    return {
        L.EMBED: fsdp,
        L.VOCAB: "model",
        L.HEADS: "model",
        L.KV: "model",
        L.FFN: "model",
        L.EXPERT: "model",
        L.LAYER: None,
        None: None,
    }


def _divisible(dim: int, axis, mesh: Mesh) -> bool:
    if axis is None:
        return True
    return dim % mesh.shape[axis] == 0


def param_pspec(spec: tuple, shape: tuple, mode: str, mesh: Mesh) -> P:
    rules = param_rules(mode, mesh)
    axes, used = [], set()
    for dim, s in zip(shape, spec):
        ax = rules.get(s)
        # drop shardings that do not divide (e.g. vocab 32001, heads 25) —
        # the flattened H*hd projections stay divisible so TP still applies —
        # and duplicates: MoE expert tensors [L,E,D,F] map only E to "model"
        # (EP), the F dim stays local to the expert shard
        if ax is not None and (ax in used or not _divisible(dim, ax, mesh)):
            ax = None
        if ax is not None:
            used.add(ax)
        axes.append(ax)
    return P(*axes)


def param_shardings(specs: dict, params: dict, mode: str, mesh: Mesh) -> dict:
    return {
        k: NamedSharding(mesh, param_pspec(specs[k], params[k].shape, mode, mesh))
        for k in params
    }


def abstract_params(params) -> dict:
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params
    )


def batch_pspec(mesh: Mesh, *, seq_sharded: bool = False) -> P:
    if seq_sharded:  # SP: batch too small to split (long_500k)
        return P(None, _dp_axes(mesh))
    return P(_dp_axes(mesh), None)


def cache_pspecs(cfg: ArchConfig, mesh: Mesh, batch: int) -> dict:
    """KV/state cache shardings: [L, B, K, S, hd] — B over data, S over model."""
    dp = "data"
    b_ax = dp if batch % mesh.shape[dp] == 0 else None
    kv = P(None, b_ax, None, "model", None)
    out = dict(k=kv, v=kv)
    if cfg.family == "hybrid":
        out["ssm"] = P(None, b_ax, None, None, None)
    if cfg.family == "ssm":
        out = dict(
            s=P(None, b_ax, "model" if cfg.n_heads % mesh.shape["model"] == 0 else None, None, None),
            tm_prev=P(None, b_ax, None),
            cm_prev=P(None, b_ax, None),
        )
    return out


def mode_for(cfg: ArchConfig) -> str:
    """fsdp_tp when TP-only weights would not fit a 16 GB chip."""
    bytes_tp = cfg.param_count() * 2 / 16  # bf16, model=16
    return "fsdp_tp" if bytes_tp > 6e9 else "tp"
