"""Packed-bitset set backend: popcount intersection for dense universes.

The all-pairs equality formulation (intersect.py / ref.py) pays O(c²)
comparisons per set pair.  When the vertex universe is small relative to
``c²`` — the overlap-heavy / high-cardinality regime the paper's real
datasets hit — a packed-bitset representation wins: each EMPTY-padded
int32 row lowers to ``uint32[ceil(n_bits/32)]`` lane words
(``pack_bitset``) and every intersection size becomes
``popcount(x & y)`` summed over words — O(n_bits/32) lane-popcount work
per pair instead of the O(c²) equality tile.

Selection rule (kernels/ops.resolve_backend): bitset is chosen
automatically when ``c² > PACK_COST·c + 2·ceil(n_bits/32)`` — the
comparison tile must outweigh both the packing pass (sort + scatter, with
a large empirical constant) and the word stream, which happens in the
high-cardinality regime (c ≳ 128).  Semantics are true *set* intersections
(duplicates within a row collapse to one bit), bit-identical to
``ref.fused_triple_stats`` on any input and to the unfused oracles on
duplicate-free rows.

Contract: row values are either ``EMPTY`` or in ``[0, n_bits)``.  Values
outside the universe cannot be represented by a fixed-width bitset and are
dropped from the packing (the counting consumers never produce them —
vertex ids are bounded by ``hg.num_vertices`` and store ranks by
``hg.n_edge_slots``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitset_words(n_bits: int) -> int:
    """uint32 words needed for a universe of ``n_bits`` values."""
    return (int(n_bits) + 31) // 32


def pack_bitset(x: jnp.ndarray, n_bits: int, *,
                assume_sorted: bool = False) -> jnp.ndarray:
    """Lower EMPTY-padded rows int32[..., c] -> uint32[..., W] lane words,
    W = ceil(n_bits/32).  Duplicate values collapse to one bit (sort +
    neighbour-dedupe before the scatter, so the word OR is a plain add);
    EMPTY and out-of-universe values contribute nothing.

    ``assume_sorted=True`` skips only the sort: the caller promises rows
    are already ascending (``read_sorted`` / ``dedupe_sorted`` output, i.e.
    every counting consumer).  The O(c) neighbour-dedupe mask is kept
    either way — duplicates in a sorted row are adjacent, so even a stored
    edge carrying a repeated vertex packs correctly (the scatter-add-as-OR
    must never see the same bit twice)."""
    W = bitset_words(n_bits)
    c = x.shape[-1]
    s = x if assume_sorted else jnp.sort(x, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s[..., :1], bool), s[..., 1:] == s[..., :-1]],
        axis=-1)
    # route dropped entries to word W exactly (W*32 >> 5 == W), never to a
    # live word — n_bits itself may land inside word W-1 when n_bits % 32
    v = jnp.where(dup | (s >= n_bits) | (s < 0), W * 32, s)
    flat = v.reshape(-1, c)
    word = flat >> 5
    bit = jnp.uint32(1) << (flat & 31).astype(jnp.uint32)
    rows = jnp.arange(flat.shape[0], dtype=jnp.int32)[:, None]
    out = jnp.zeros((flat.shape[0], W + 1), jnp.uint32)
    out = out.at[rows, word].add(bit)
    return out[:, :W].reshape(x.shape[:-1] + (W,))


def _popcount_sum(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


def pair_intersect_count(x, y, *, n_bits: int, assume_sorted: bool = False):
    """|X_i ∩ Y_i| via popcount. x, y: int32[n, c] -> int32[n]."""
    return _popcount_sum(pack_bitset(x, n_bits, assume_sorted=assume_sorted)
                         & pack_bitset(y, n_bits, assume_sorted=assume_sorted))


def stack_pair_intersect_count(a, cand, *, n_bits: int,
                               assume_sorted: bool = False):
    """|A_i ∩ C_ik|. a: int32[n,c]; cand: int32[n,k,c] -> int32[n,k]."""
    return _popcount_sum(
        pack_bitset(a, n_bits, assume_sorted=assume_sorted)[:, None, :]
        & pack_bitset(cand, n_bits, assume_sorted=assume_sorted))


def triple_intersect_count(a, b, cand, *, n_bits: int,
                           assume_sorted: bool = False):
    """|A_i ∩ B_i ∩ C_ik| -> int32[n,k]."""
    ab = (pack_bitset(a, n_bits, assume_sorted=assume_sorted)
          & pack_bitset(b, n_bits, assume_sorted=assume_sorted))
    return _popcount_sum(
        ab[:, None, :] & pack_bitset(cand, n_bits, assume_sorted=assume_sorted))


def fused_triple_stats(a, b, cand, *, n_bits: int, assume_sorted: bool = False):
    """All four joint sizes from one packing of the three operands — the
    bitset twin of ``ref.fused_triple_stats`` (same tuple, bit-identical).
    ``assume_sorted`` as in ``pack_bitset``."""
    A = pack_bitset(a, n_bits, assume_sorted=assume_sorted)   # [n, W]
    B = pack_bitset(b, n_bits, assume_sorted=assume_sorted)
    C = pack_bitset(cand, n_bits, assume_sorted=assume_sorted)  # [n, k, W]
    ab = A & B
    iab = _popcount_sum(ab)
    iac = _popcount_sum(A[:, None, :] & C)
    ibc = _popcount_sum(B[:, None, :] & C)
    iabc = _popcount_sum(ab[:, None, :] & C)
    return iab, iac, ibc, iabc
