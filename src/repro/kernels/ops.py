"""Jit'd public wrappers around the intersection kernels, three backends.

``backend="pallas"`` runs the real kernels (interpret=True off-TPU, compiled
Mosaic on TPU); ``backend="xla"`` uses the pure-jnp oracles — bit-identical
semantics, used on CPU hosts where interpret-mode would be slow, and as the
lowering path for the multi-pod dry-run (Mosaic kernels only lower for TPU
targets); ``backend="bitset"`` packs rows to uint32 lane words and counts
with ``popcount(x & y)`` (kernels/bitset.py) — it needs the universe bound
``n_bits`` and wins in the high-cardinality regime.

Backend selection (``resolve_backend``): an explicit string always wins;
``None`` resolves to bitset when the caller supplies ``(c, n_bits)`` and the
equality tile outweighs pack + word-stream work
(``c² > PACK_COST·c + 2·ceil(n_bits/32)``), else to
the platform default.  All three backends produce bit-identical triad
histograms because the counting consumers only feed duplicate-free sorted
rows (validated in tests/test_backend_parity.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import bitset as _bitset
from repro.kernels import intersect as _pallas
from repro.kernels import ref as _ref

BACKENDS = ("pallas", "xla", "bitset")

# Packing one row element (sort + scatter word build) costs about this many
# equality-tile comparisons' worth of time — empirical, CPU XLA (see the
# calibration table in DESIGN.md §2.5).  The bitset backend only wins once
# the c² tile outweighs pack + word-stream work, which in practice means the
# high-cardinality regime (c ≳ 128) over a dense-enough universe.
PACK_COST = 100

_DEFAULT = None


def default_backend() -> str:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = "pallas" if jax.default_backend() == "tpu" else "xla"
    return _DEFAULT


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_backend(
    backend: str | None = None, *, c: int | None = None,
    n_bits: int | None = None,
) -> str:
    """Resolve ``backend`` to a concrete kernel choice, validating it.

    Callers that fix the dispatch once per computation — the sharded drivers
    in ``distributed/triads.py``, where every device of a ``shard_map`` body
    must lower the *same* kernel — resolve here, outside the sharded region,
    and pass the concrete string down.

    ``None`` auto-selects: when the static set width ``c`` and universe
    bound ``n_bits`` are supplied and the equality tile outweighs the
    bitset's pack + word-stream work —

        c² > PACK_COST · c + 2 · ceil(n_bits/32)

    — the bitset backend is chosen; otherwise the platform default (pallas
    on TPU, xla elsewhere).  The cost rule is calibrated against CPU XLA,
    so auto-bitset only applies where the default would be xla — on TPU the
    fused Mosaic kernel is the measured-fast path and ``None`` keeps it
    (force ``backend="bitset"`` explicitly to override).  Resolution is
    idempotent: a concrete string passes through unchanged, so nested
    resolves agree."""
    if backend is None:
        if (c is not None and n_bits is not None
                and default_backend() != "pallas"
                and c * c > PACK_COST * c + 2 * _bitset.bitset_words(n_bits)):
            return "bitset"
        return default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}")
    return backend


def _require_n_bits(n_bits: int | None, op: str) -> int:
    if n_bits is None:
        raise ValueError(
            f"backend='bitset' needs the universe bound n_bits for {op}")
    return n_bits


def pair_intersect_count(x, y, *, backend: str | None = None,
                         n_bits: int | None = None,
                         assume_sorted: bool = False):
    backend = resolve_backend(backend, c=x.shape[-1], n_bits=n_bits)
    if backend == "pallas":
        return _pallas.pair_intersect_count(x, y, interpret=_interpret())
    if backend == "bitset":
        return _bitset.pair_intersect_count(
            x, y, n_bits=_require_n_bits(n_bits, "pair_intersect_count"),
            assume_sorted=assume_sorted)
    return _ref.pair_intersect_count(x, y)


def membership(x, y, *, backend: str | None = None):
    backend = resolve_backend(backend)
    if backend == "bitset":
        # no bitset lowering: the output is per-*element*, not a set size —
        # fail loud rather than silently serving the xla result
        raise ValueError("membership has no bitset lowering (per-element "
                         "output); use backend='xla' or 'pallas'")
    if backend == "pallas":
        return _pallas.membership(x, y, interpret=_interpret())
    return _ref.membership(x, y)


def triple_intersect_count(a, b, cand, *, backend: str | None = None,
                           n_bits: int | None = None,
                           assume_sorted: bool = False):
    backend = resolve_backend(backend, c=a.shape[-1], n_bits=n_bits)
    if backend == "pallas":
        return _pallas.triple_intersect_count(a, b, cand, interpret=_interpret())
    if backend == "bitset":
        return _bitset.triple_intersect_count(
            a, b, cand,
            n_bits=_require_n_bits(n_bits, "triple_intersect_count"),
            assume_sorted=assume_sorted)
    return _ref.triple_intersect_count(a, b, cand)


def stack_pair_intersect_count(a, cand, *, backend: str | None = None,
                               n_bits: int | None = None,
                               assume_sorted: bool = False):
    backend = resolve_backend(backend, c=a.shape[-1], n_bits=n_bits)
    if backend == "pallas":
        return _pallas.stack_pair_intersect_count(a, cand, interpret=_interpret())
    if backend == "bitset":
        return _bitset.stack_pair_intersect_count(
            a, cand,
            n_bits=_require_n_bits(n_bits, "stack_pair_intersect_count"),
            assume_sorted=assume_sorted)
    return _ref.stack_pair_intersect_count(a, cand)


def fused_triple_stats(a, b, cand, *, backend: str | None = None,
                       n_bits: int | None = None, assume_sorted: bool = False):
    """One launch, all four joint intersection sizes of (A_i, B_i, C_ik):
    ``(iab[n], iac[n,k], ibc[n,k], iabc[n,k])`` — the probe hot path.
    True set semantics on every backend (duplicates count once).

    ``n_bits`` (universe bound: vertex count for h2v rows, edge-slot count
    for v2h rows) enables the bitset backend and, together with
    ``c = a.shape[-1]``, drives auto-selection when ``backend`` is None.
    ``assume_sorted=True`` promises rows are already sorted ascending
    (read_sorted / dedupe_sorted output), letting the bitset packing skip
    its sort — the O(c) adjacent-duplicate mask is kept, so repeated values
    still collapse correctly.  The counting consumers all qualify."""
    backend = resolve_backend(backend, c=a.shape[-1], n_bits=n_bits)
    if backend == "pallas":
        return _pallas.fused_triple_stats(a, b, cand, interpret=_interpret())
    if backend == "bitset":
        return _bitset.fused_triple_stats(
            a, b, cand, n_bits=_require_n_bits(n_bits, "fused_triple_stats"),
            assume_sorted=assume_sorted)
    return _ref.fused_triple_stats(a, b, cand)
