"""Jit'd public wrappers around the Pallas kernels with an XLA fallback.

``backend="pallas"`` runs the real kernels (interpret=True off-TPU, compiled
Mosaic on TPU); ``backend="xla"`` uses the pure-jnp oracles — bit-identical
semantics, used on CPU hosts where interpret-mode would be slow, and as the
lowering path for the multi-pod dry-run (Mosaic kernels only lower for TPU
targets).  Default is resolved once from the platform.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import intersect as _pallas
from repro.kernels import ref as _ref

_DEFAULT = None


def default_backend() -> str:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = "pallas" if jax.default_backend() == "tpu" else "xla"
    return _DEFAULT


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_backend(backend: str | None = None) -> str:
    """Resolve ``backend`` to a concrete kernel choice, validating it.

    Callers that fix the dispatch once per computation — the sharded drivers
    in ``distributed/triads.py``, where every device of a ``shard_map`` body
    must lower the *same* kernel — resolve here, outside the sharded region,
    and pass the concrete string down.  ``None`` resolves from the platform
    exactly like the per-op wrappers below."""
    b = backend or default_backend()
    if b not in ("pallas", "xla"):
        raise ValueError(f"unknown kernel backend {b!r}")
    return b


def pair_intersect_count(x, y, *, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "pallas":
        return _pallas.pair_intersect_count(x, y, interpret=_interpret())
    return _ref.pair_intersect_count(x, y)


def membership(x, y, *, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "pallas":
        return _pallas.membership(x, y, interpret=_interpret())
    return _ref.membership(x, y)


def triple_intersect_count(a, b, cand, *, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "pallas":
        return _pallas.triple_intersect_count(a, b, cand, interpret=_interpret())
    return _ref.triple_intersect_count(a, b, cand)


def stack_pair_intersect_count(a, cand, *, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "pallas":
        return _pallas.stack_pair_intersect_count(a, cand, interpret=_interpret())
    return _ref.stack_pair_intersect_count(a, cand)
