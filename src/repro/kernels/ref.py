"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function of the same name here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = jnp.iinfo(jnp.int32).max


def pair_intersect_count(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """|X_i ∩ Y_i| for batched padded sets. x, y: int32[n, c] (EMPTY pads).

    Elements within a row are assumed distinct (set semantics).
    """
    eq = x[:, :, None] == y[:, None, :]
    valid = (x[:, :, None] != EMPTY) & (y[:, None, :] != EMPTY)
    return jnp.sum(eq & valid, axis=(1, 2)).astype(jnp.int32)


def membership(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """For each element of X_i, whether it appears in Y_i. -> int32[n, c]."""
    eq = (x[:, :, None] == y[:, None, :]) & (y[:, None, :] != EMPTY)
    hit = jnp.any(eq, axis=2) & (x != EMPTY)
    return hit.astype(jnp.int32)


def stack_pair_intersect_count(a, cand):
    """|A_i ∩ C_ik|. a: int32[n,c]; cand: int32[n,k,c] -> int32[n,k].
    (= triple_intersect_count(a, a, cand) without the redundant A∈A
    membership pass — §Perf iteration E3.)"""
    eq = (a[:, None, :, None] == cand[:, :, None, :]) & (cand[:, :, None, :] != EMPTY)
    in_c = jnp.any(eq, axis=3) & (a[:, None, :] != EMPTY)
    return jnp.sum(in_c, axis=2).astype(jnp.int32)


def triple_intersect_count(a, b, cand):
    """|A_i ∩ B_i ∩ C_ik| for candidate stacks. a,b: int32[n,c]; cand:
    int32[n,k,c] -> int32[n,k]."""
    in_b = membership(a, b)                                # [n, c]
    eq = (a[:, None, :, None] == cand[:, :, None, :]) & (cand[:, :, None, :] != EMPTY)
    in_c = jnp.any(eq, axis=3) & (a[:, None, :] != EMPTY)  # [n, k, c]
    return jnp.sum(in_c & (in_b[:, None, :] == 1), axis=2).astype(jnp.int32)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None):
    """Reference attention. q,k,v: [b, h, s, d] (k/v may have fewer heads —
    GQA is the caller's job; here heads match). f32 accumulation."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32) * scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, k.astype(jnp.float32))
    s_q, s_k = q.shape[2], k.shape[2]
    pos_q = jnp.arange(s_q)[:, None] + (s_k - s_q)  # right-aligned decode offset
    pos_k = jnp.arange(s_k)[None, :]
    mask = jnp.ones((s_q, s_k), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
