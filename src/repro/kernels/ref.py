"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function of the same name here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = jnp.iinfo(jnp.int32).max


def pair_intersect_count(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """|X_i ∩ Y_i| for batched padded sets. x, y: int32[n, c] (EMPTY pads).

    Elements within a row are assumed distinct (set semantics).
    """
    eq = x[:, :, None] == y[:, None, :]
    valid = (x[:, :, None] != EMPTY) & (y[:, None, :] != EMPTY)
    return jnp.sum(eq & valid, axis=(1, 2)).astype(jnp.int32)


def membership(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """For each element of X_i, whether it appears in Y_i. -> int32[n, c]."""
    eq = (x[:, :, None] == y[:, None, :]) & (y[:, None, :] != EMPTY)
    hit = jnp.any(eq, axis=2) & (x != EMPTY)
    return hit.astype(jnp.int32)


def stack_pair_intersect_count(a, cand):
    """|A_i ∩ C_ik|. a: int32[n,c]; cand: int32[n,k,c] -> int32[n,k].
    (= triple_intersect_count(a, a, cand) without the redundant A∈A
    membership pass — §Perf iteration E3.)"""
    eq = (a[:, None, :, None] == cand[:, :, None, :]) & (cand[:, :, None, :] != EMPTY)
    in_c = jnp.any(eq, axis=3) & (a[:, None, :] != EMPTY)
    return jnp.sum(in_c, axis=2).astype(jnp.int32)


def triple_intersect_count(a, b, cand):
    """|A_i ∩ B_i ∩ C_ik| for candidate stacks. a,b: int32[n,c]; cand:
    int32[n,k,c] -> int32[n,k]."""
    in_b = membership(a, b)                                # [n, c]
    eq = (a[:, None, :, None] == cand[:, :, None, :]) & (cand[:, :, None, :] != EMPTY)
    in_c = jnp.any(eq, axis=3) & (a[:, None, :] != EMPTY)  # [n, k, c]
    return jnp.sum(in_c & (in_b[:, None, :] == 1), axis=2).astype(jnp.int32)


def first_occurrence(x):
    """Mask of the first occurrence of each distinct non-EMPTY value in each
    row — the dedupe mask that gives the fused stats true *set* semantics
    even on rows with repeated values. x: int32[n, c] -> bool[n, c]."""
    c = x.shape[-1]
    earlier = jnp.arange(c)[None, :] < jnp.arange(c)[:, None]   # [c, c] j < i
    dup = jnp.any((x[:, :, None] == x[:, None, :]) & earlier, axis=2)
    return ~dup & (x != EMPTY)


def fused_triple_stats(a, b, cand):
    """All four joint intersection sizes of the triple (A_i, B_i, C_ik) from
    one pass over the three sets (the Venn-region statistics the triad
    classifier consumes):

        iab[n]    = |A_i ∩ B_i|
        iac[n,k]  = |A_i ∩ C_ik|
        ibc[n,k]  = |B_i ∩ C_ik|
        iabc[n,k] = |A_i ∩ B_i ∩ C_ik|

    Semantics are true *set* intersections: repeated values within a row
    count once (first-occurrence masks), so the result is bit-identical to
    the packed-bitset backend on any input.  On duplicate-free rows it
    equals the unfused (pair/stack/triple) oracles above."""
    fa = first_occurrence(a)                               # [n, c]
    fb = first_occurrence(b)
    in_b = (membership(a, b) == 1)                         # [n, c]
    ab = in_b & fa
    iab = jnp.sum(ab, axis=1).astype(jnp.int32)
    cv = cand[:, :, None, :] != EMPTY
    in_ca = jnp.any((a[:, None, :, None] == cand[:, :, None, :]) & cv, axis=3)
    in_cb = jnp.any((b[:, None, :, None] == cand[:, :, None, :]) & cv, axis=3)
    iac = jnp.sum(in_ca & fa[:, None, :], axis=2).astype(jnp.int32)
    ibc = jnp.sum(in_cb & fb[:, None, :], axis=2).astype(jnp.int32)
    iabc = jnp.sum(in_ca & ab[:, None, :], axis=2).astype(jnp.int32)
    return iab, iac, ibc, iabc


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None):
    """Reference attention. q,k,v: [b, h, s, d] (k/v may have fewer heads —
    GQA is the caller's job; here heads match). f32 accumulation."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32) * scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, k.astype(jnp.float32))
    s_q, s_k = q.shape[2], k.shape[2]
    pos_q = jnp.arange(s_q)[:, None] + (s_k - s_q)  # right-aligned decode offset
    pos_k = jnp.arange(s_k)[None, :]
    mask = jnp.ones((s_q, s_k), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
