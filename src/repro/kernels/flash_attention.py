"""Blockwise (flash) attention Pallas kernel for TPU.

Perf-critical layer of the LM substrate (prefill and training).  Online
softmax with f32 accumulators in VMEM scratch; grid iterates KV blocks in
the innermost ("arbitrary") axis so the accumulator lives across steps.

  grid = (batch, q_heads, q_blocks, kv_blocks)
  Q block   (1, 1, bq, d)  VMEM
  K/V block (1, 1, bk, d)  VMEM — GQA mapped by index arithmetic, no
                           materialised head repetition
  scratch   acc[bq, d] f32, m[bq] f32, l[bq] f32

Supports causal masking, right-aligned decode offsets (s_q < s_kv), and a
sliding window (Hymba).  Block sizes default to 128 (MXU/lane aligned).
Validated in interpret mode against ``ref.flash_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(-1e30)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale, causal, window, bq, bk, s_q, s_kv, n_kv_blocks):
    j = pl.program_id(2)
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = j * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (s_kv - s_q)
    k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < s_kv                      # guard kv padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    # zero out-of-range KV rows: grid padding fills them with undefined
    # values and 0 * undefined would poison the accumulator
    kv_valid = (kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)) < s_kv
    v = jnp.where(kv_valid, v_ref[0, 0].astype(jnp.float32), 0.0)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(kk == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    scale: float | None = None,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """q: [b, hq, s_q, d]; k, v: [b, hkv, s_kv, d]; hq % hkv == 0."""
    b, hq, s_q, d = q.shape
    _, hkv, s_kv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    bq = min(block_q, s_q)
    bk = min(block_k, s_kv)
    nq = pl.cdiv(s_q, bq)
    nk = pl.cdiv(s_kv, bk)
    grid = (b, hq, nq, nk)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, s_q=s_q, s_kv=s_kv, n_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, j, kk: (ib, ih, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, j, kk: (ib, ih // group, kk, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, j, kk: (ib, ih // group, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, j, kk: (ib, ih, j, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
