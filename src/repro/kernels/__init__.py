"""Set-intersection kernel surface (DESIGN.md §2.3–§2.5).

Public entry points re-exported from ``repro.kernels.ops`` — the jit'd
three-backend dispatch layer (``pallas`` Mosaic kernels on TPU, ``xla``
jnp oracles, ``bitset`` packed lane-popcount) — so consumers write
``from repro import kernels; kernels.fused_triple_stats(...)`` instead of
reaching into the backend modules.  ``kernels.intersect`` (Pallas),
``kernels.ref`` (oracles) and ``kernels.bitset`` (packing) remain the
private lowerings behind this surface.
"""
from repro.kernels.ops import (
    BACKENDS,
    default_backend,
    fused_triple_stats,
    membership,
    pair_intersect_count,
    resolve_backend,
    stack_pair_intersect_count,
    triple_intersect_count,
)

__all__ = [
    "BACKENDS", "default_backend", "fused_triple_stats", "membership",
    "pair_intersect_count", "resolve_backend", "stack_pair_intersect_count",
    "triple_intersect_count",
]
