"""Pallas TPU kernels for batched sorted-set intersection counting.

The paper's triad hot spot is the adjacency-list intersection of two
hyperedges (§IV, "parallel sorted set intersection as in [18]").  The GPU
reference is a merge-path two-pointer walk — divergent control flow that a
TPU vector unit cannot execute efficiently.  The TPU-native formulation
(DESIGN.md §2) is an *all-pairs equality reduce*: for padded sets of width
``c`` we materialise the ``c × c`` comparison tile in VMEM and reduce it.
That is O(c^2) comparisons instead of O(c), but they run at full VPU rate
with zero divergence, the tile never leaves VMEM, and for the cardinalities
that dominate the paper's datasets (≤ a few hundred) the kernel is firmly
memory-bound on the HBM→VMEM stream of the set rows themselves — i.e. the
extra flops are free.

Grid/Block design
  * grid over row tiles: each program instance owns ``block_rows`` set pairs;
  * BlockSpec keeps rows in VMEM: 2 × block_rows × c × 4B plus the boolean
    tile block_rows × c × c — sized so the working set stays ≤ ~2 MiB
    (``block_rows`` auto-shrinks as ``c`` grows);
  * last dim padded to the 128-lane boundary by the wrapper (ops.py).

All kernels run under ``interpret=True`` on CPU for validation against
``ref.py``; on TPU the same ``pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY = jnp.iinfo(jnp.int32).max


def _pair_count_kernel(x_ref, y_ref, out_ref):
    x = x_ref[...]                        # [bn, c]
    y = y_ref[...]                        # [bn, c]
    eq = (x[:, :, None] == y[:, None, :]) & (y[:, None, :] != EMPTY) & (
        x[:, :, None] != EMPTY
    )
    out_ref[...] = jnp.sum(eq, axis=(1, 2)).astype(jnp.int32)


def pick_block_rows(c: int, budget_bytes: int = 2 * 1024 * 1024) -> int:
    """Rows per program instance so the eq tile + operands fit the budget."""
    per_row = c * c + 2 * c * 4
    return max(1, min(256, budget_bytes // max(per_row, 1)))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def pair_intersect_count(x, y, *, interpret: bool = True, block_rows: int | None = None):
    """|X_i ∩ Y_i| for int32[n, c] EMPTY-padded rows -> int32[n]."""
    n, c = x.shape
    bn = block_rows or pick_block_rows(c)
    bn = min(bn, n)
    grid = (pl.cdiv(n, bn),)
    return pl.pallas_call(
        _pair_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, c), lambda i: (i, 0)),
            pl.BlockSpec((bn, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(x, y)


def _membership_kernel(x_ref, y_ref, out_ref):
    x = x_ref[...]
    y = y_ref[...]
    eq = (x[:, :, None] == y[:, None, :]) & (y[:, None, :] != EMPTY)
    hit = jnp.any(eq, axis=2) & (x != EMPTY)
    out_ref[...] = hit.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def membership(x, y, *, interpret: bool = True, block_rows: int | None = None):
    """Per-element membership of X_i in Y_i -> int32[n, c]."""
    n, c = x.shape
    bn = min(block_rows or pick_block_rows(c), n)
    grid = (pl.cdiv(n, bn),)
    return pl.pallas_call(
        _membership_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, c), lambda i: (i, 0)),
            pl.BlockSpec((bn, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.int32),
        interpret=interpret,
    )(x, y)


def _stack_pair_kernel(a_ref, cand_ref, out_ref):
    a = a_ref[...]                        # [bn, c]
    cand = cand_ref[...]                  # [bn, bk, c]
    eq = (a[:, None, :, None] == cand[:, :, None, :]) & (
        cand[:, :, None, :] != EMPTY
    )
    in_c = jnp.any(eq, axis=3) & (a[:, None, :] != EMPTY)
    out_ref[...] = jnp.sum(in_c, axis=2).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows", "block_k"))
def stack_pair_intersect_count(
    a, cand, *, interpret: bool = True, block_rows: int | None = None, block_k: int = 8
):
    """|A_i ∩ C_ik| against a candidate stack -> int32[n,k]."""
    n, c = a.shape
    k = cand.shape[1]
    bn = min(block_rows or max(1, pick_block_rows(c) // max(block_k, 1)), n)
    bk = min(block_k, k)
    grid = (pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        _stack_pair_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bk, c), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.int32),
        interpret=interpret,
    )(a, cand)


def _triple_count_kernel(a_ref, b_ref, cand_ref, out_ref):
    a = a_ref[...]                        # [bn, c]
    b = b_ref[...]                        # [bn, c]
    cand = cand_ref[...]                  # [bn, bk, c]
    # A∩B membership computed in-kernel per row tile, reused across all bk
    # candidates — one launch, no separate membership kernel
    inb = jnp.any(
        (a[:, :, None] == b[:, None, :]) & (b[:, None, :] != EMPTY), axis=2
    ) & (a != EMPTY)
    eq = (a[:, None, :, None] == cand[:, :, None, :]) & (
        cand[:, :, None, :] != EMPTY
    )
    in_c = jnp.any(eq, axis=3) & (a[:, None, :] != EMPTY)    # [bn, bk, c]
    out_ref[...] = jnp.sum(in_c & inb[:, None, :], axis=2).astype(jnp.int32)


def _fused_stats_kernel(a_ref, b_ref, cand_ref,
                        iab_ref, iac_ref, ibc_ref, iabc_ref):
    a = a_ref[...]                        # [bn, c]
    b = b_ref[...]                        # [bn, c]
    cand = cand_ref[...]                  # [bn, bk, c]
    c = a.shape[1]
    # j < i lower-triangle via iota (TPU-safe; no jnp.tril in Mosaic)
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    earlier = jj < ii
    # row-level masks, computed once and reused across all bk candidates
    fa = ~jnp.any((a[:, :, None] == a[:, None, :]) & earlier, axis=2) & (
        a != EMPTY
    )
    fb = ~jnp.any((b[:, :, None] == b[:, None, :]) & earlier, axis=2) & (
        b != EMPTY
    )
    in_b = jnp.any(
        (a[:, :, None] == b[:, None, :]) & (b[:, None, :] != EMPTY), axis=2
    ) & (a != EMPTY)
    ab = in_b & fa
    iab_ref[...] = jnp.sum(ab, axis=1).astype(jnp.int32)
    # candidate tiles: two eq tiles per candidate, three counts out
    cv = cand[:, :, None, :] != EMPTY
    in_ca = jnp.any((a[:, None, :, None] == cand[:, :, None, :]) & cv, axis=3)
    in_cb = jnp.any((b[:, None, :, None] == cand[:, :, None, :]) & cv, axis=3)
    iac_ref[...] = jnp.sum(in_ca & fa[:, None, :], axis=2).astype(jnp.int32)
    ibc_ref[...] = jnp.sum(in_cb & fb[:, None, :], axis=2).astype(jnp.int32)
    iabc_ref[...] = jnp.sum(in_ca & ab[:, None, :], axis=2).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows", "block_k"))
def fused_triple_stats(
    a, b, cand, *, interpret: bool = True,
    block_rows: int | None = None, block_k: int = 8,
):
    """One-pass multi-intersection: ``(iab[n], iac[n,k], ibc[n,k],
    iabc[n,k])`` for a,b: int32[n,c] and cand: int32[n,k,c] (EMPTY-padded).

    Each A/B row and each candidate tile is loaded into VMEM exactly once;
    the A∩B membership vector and the first-occurrence dedupe masks are
    computed per row tile and reused across all ``bk`` candidates — one
    kernel launch instead of the five the unfused sequence needs
    (pair + membership + 2× stack + triple).  Set semantics match
    ``ref.fused_triple_stats`` bit-exactly, duplicates included.

    VMEM per program instance: 3 row tiles of ``bn·c²`` bools (fa/fb/in_b
    comparisons) plus 2 candidate tiles of ``bn·bk·c²`` bools; block_rows
    auto-shrinks by ``2·bk + 3`` (candidate AND row tiles both count) so
    the working set stays in budget."""
    n, c = a.shape
    k = cand.shape[1]
    bk = min(block_k, k)
    # clamp bk BEFORE sizing bn: a k=1 stack (count_triads_containing) has a
    # bn·(2·1+3)·c² working set, not bn·(2·block_k+3)·c²
    bn = min(block_rows or max(1, pick_block_rows(c) // (2 * bk + 3)), n)
    grid = (pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        _fused_stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bk, c), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            # every j program writes the same iab row block — redundant but
            # race-free (identical values), and it keeps the grid 2-D
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
        ],
        interpret=interpret,
    )(a, b, cand)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows", "block_k"))
def triple_intersect_count(
    a, b, cand, *, interpret: bool = True, block_rows: int | None = None, block_k: int = 8
):
    """|A_i ∩ B_i ∩ C_ik|. a,b: int32[n,c]; cand: int32[n,k,c] -> int32[n,k].

    The A∩B membership vector is computed once per row tile *inside* the
    kernel and re-used across all k candidates — the same factorisation the
    paper uses when it scans h_k ∈ N(h_i) ∪ N(h_j) for a fixed (h_i, h_j),
    in ONE launch (membership is no longer a separate kernel).
    """
    n, c = a.shape
    k = cand.shape[1]
    bk = min(block_k, k)
    # bk candidate tiles + 1 in-kernel membership tile, all bn·c² bools
    bn = min(block_rows or max(1, pick_block_rows(c) // (bk + 1)), n)
    grid = (pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        _triple_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bk, c), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.int32),
        interpret=interpret,
    )(a, b, cand)
