"""Batched serving engine with an ESCHER-style cache-slot pool.

The KV cache is a fixed pool of per-sequence slots (capacity = max
concurrent sequences).  Finished sequences *free* their slot; new requests
*reuse* freed slots without reallocation — the same preallocate/mark-free/
reuse discipline as the paper's block manager (DESIGN.md §4), applied to
serving memory.  Continuous batching: each engine step decodes every active
slot; arrivals fill free slots at step boundaries.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api
from repro.serve import serve_step as SRV


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # int32[prompt_len]
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int, max_seq: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.cache = api.init_decode_state(cfg, slots, max_seq, dtype)
        self.free = deque(range(slots))            # ESCHER-style slot pool
        self.active: dict[int, Request] = {}       # slot -> request
        self.pos = np.zeros(slots, np.int32)
        self.queue: deque[Request] = deque()
        self.decode = jax.jit(SRV.make_decode(cfg))
        self._prefill_cache = {}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request) -> None:
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        plen = tokens.shape[1]
        prefill = self._prefill_cache.get(plen)
        if prefill is None:
            prefill = jax.jit(SRV.make_prefill(self.cfg, self.max_seq))
            self._prefill_cache[plen] = prefill
        one_cache = jax.tree_util.tree_map(
            lambda a: a[:, slot:slot + 1] if a.ndim > 1 else a, self.cache)
        logits, one_cache = prefill(self.params, tokens, one_cache)
        self.cache = jax.tree_util.tree_map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1),
            self.cache, one_cache)
        nxt = int(jnp.argmax(logits[-1] if logits.ndim == 1 else logits[0]))
        req.out.append(nxt)
        self.pos[slot] = plen

    def step(self) -> list[Request]:
        """Admit → decode one token for all active slots → retire."""
        while self.queue and self.free:
            slot = self.free.popleft()        # reuse freed slot (no realloc)
            req = self.queue.popleft()
            self.active[slot] = req
            self._prefill_one(slot, req)

        finished = []
        if self.active:
            toks = np.zeros((self.slots, 1), np.int32)
            for slot, req in self.active.items():
                toks[slot, 0] = req.out[-1]
            # single batched decode across the whole pool (idle slots waste
            # one token of compute — the continuous-batching trade)
            pos = jnp.asarray(int(max(self.pos[s] for s in self.active)), jnp.int32)
            logits, self.cache = self.decode(
                self.params, jnp.asarray(toks), self.cache, pos)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for slot, req in list(self.active.items()):
                req.out.append(int(nxt[slot]))
                self.pos[slot] += 1
                if len(req.out) >= req.max_new + 1 or self.pos[slot] >= self.max_seq - 1:
                    req.done = True
                    finished.append(req)
                    del self.active[slot]
                    self.free.append(slot)     # slot back in the pool
        return finished

    def run(self) -> list[Request]:
        done = []
        while self.queue or self.active:
            done += self.step()
        return done
