"""Serving steps: prefill (context → cache + first logits) and decode
(one token against the cache).  ``decode_*`` / ``long_*`` dry-run cells
lower ``decode_step`` — one new token with a seq_len-deep cache.

The KV cache pool follows ESCHER's block-reuse idea (DESIGN.md §4): the
engine (serve/engine.py) manages fixed-capacity per-sequence cache slots and
reuses freed slots on eviction instead of reallocating."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api


def make_prefill(cfg: ArchConfig, max_seq: int):
    def prefill(params, tokens, cache, **kw):
        """tokens [B,S]; cache pre-allocated for max_seq."""
        logits, new_cache, _ = api.forward(
            cfg, params, tokens, cache=cache, cache_pos=jnp.int32(0),
            remat=False, **kw)
        return logits[:, -1], new_cache

    if cfg.family in ("ssm",):
        def prefill(params, tokens, cache, **kw):  # noqa: F811 — state models
            logits, state, _ = api.forward(cfg, params, tokens, cache=cache, **kw)
            return logits[:, -1], state
    return prefill


def make_decode(cfg: ArchConfig):
    def decode(params, token, cache, pos, **kw):
        """token [B,1]; pos scalar int32 — absolute position of this token."""
        logits, new_cache, _ = api.forward(
            cfg, params, token, cache=cache, cache_pos=pos,
            positions=pos + jnp.arange(1), remat=False, **kw)
        return logits[:, -1], new_cache

    if cfg.family in ("ssm",):
        def decode(params, token, cache, pos, **kw):  # noqa: F811
            logits, state, _ = api.forward(cfg, params, token, cache=cache, **kw)
            return logits[:, -1], state
    return decode
