"""End-to-end behaviour: the paper's system (dynamic triad maintenance on
ESCHER) and the LM framework driver, exercised through the public APIs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import update as U
from repro.core.store import EMPTY
from conftest import rand_hyperedges

pytestmark = pytest.mark.slow


def test_end_to_end_dynamic_triad_maintenance():
    """Build → churn × 4 → counts always equal a from-scratch recount, while
    the store reuses freed blocks (free_ptr growth is bounded by overflow
    allocations only)."""
    rng = np.random.default_rng(42)
    V = 20
    hg = H.from_lists(rand_hyperedges(rng, 30, V), num_vertices=V,
                      max_edges=128, max_card=8)
    counts = BL.mochy_static(hg, max_deg=64, max_region=127, chunk=256)
    for it in range(4):
        present = np.asarray(hg.h2v.mgr.present)
        live = np.asarray(hg.h2v.mgr.hid)[present == 1]
        dels = rng.choice(live, size=6, replace=False).astype(np.int32)
        newe = rand_hyperedges(rng, 6, V)
        nl = np.full((6, 8), EMPTY, np.int32)
        nc = np.zeros(6, np.int32)
        for i, e in enumerate(newe):
            nl[i, : len(e)] = sorted(e)
            nc[i] = len(e)
        hg, counts, _ = U.update_triad_counts(
            hg, counts, jnp.asarray(dels), jnp.ones(6, bool),
            jnp.asarray(nl), jnp.asarray(nc), jnp.ones(6, bool),
            max_deg=64, max_region=127, chunk=256)
        ref = BL.mochy_static(hg, max_deg=64, max_region=127, chunk=256)
        assert (np.asarray(counts) == np.asarray(ref)).all()
    assert int(hg.h2v.error) == 0 and int(hg.v2h.error) == 0


def test_end_to_end_training_improves_loss(tmp_path):
    from repro.launch.train import main
    losses = main([
        "--arch", "qwen2.5-3b", "--reduced", "--steps", "25",
        "--batch", "4", "--seq", "64", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "10",
    ])
    assert losses[-1] < losses[0] - 0.1


def test_end_to_end_serving(capsys):
    from repro.launch.serve import main
    done = main(["--arch", "qwen2.5-3b", "--reduced", "--requests", "3",
                 "--slots", "2", "--max-new", "4", "--max-seq", "64"])
    assert len(done) == 3
    assert all(len(r.out) == 5 for r in done)
