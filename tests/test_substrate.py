"""Optimizer, data pipeline, checkpoint, fault recovery, compression,
serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.distributed import compression as COMP
from repro.models import api
from repro.serve.engine import Engine, Request
from repro.train import checkpoint as CKPT
from repro.train import data as DATA
from repro.train import fault as FAULT
from repro.train import optimizer as OPT
from repro.train import train_step as TS


# ---------------------------------------------------------------- optimizer
def test_adamw_matches_numpy_reference():
    c = OPT.AdamWConfig(lr=1e-2, weight_decay=0.1, warmup_steps=0,
                        total_steps=10**9, clip_norm=1e9, min_lr_frac=1.0)
    params = dict(w=jnp.asarray([[1.0, -2.0], [0.5, 3.0]]))
    opt = OPT.init_state(params)
    grads = dict(w=jnp.asarray([[0.1, 0.2], [-0.3, 0.4]]))
    new_p, new_opt, _ = OPT.apply_updates(c, params, opt, grads)

    g = np.asarray(grads["w"])
    m = 0.1 * g
    v = 0.05 * g ** 2
    upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.95)) + c.eps)
    upd += 0.1 * np.asarray(params["w"])
    exp = np.asarray(params["w"]) - 1e-2 * upd
    np.testing.assert_allclose(np.asarray(new_p["w"]), exp, rtol=1e-5)
    assert int(new_opt["step"]) == 1


def test_norms_excluded_from_weight_decay():
    c = OPT.AdamWConfig(lr=1e-2, weight_decay=1.0, warmup_steps=0,
                        total_steps=10**9, min_lr_frac=1.0)
    params = dict(final_norm=jnp.ones(4), w=jnp.ones(4))
    opt = OPT.init_state(params)
    grads = dict(final_norm=jnp.zeros(4), w=jnp.zeros(4))
    new_p, _, _ = OPT.apply_updates(c, params, opt, grads)
    assert float(jnp.abs(new_p["final_norm"] - 1).max()) == 0   # untouched
    assert float(jnp.abs(new_p["w"] - 1).max()) > 0             # decayed


def test_schedule_warmup_and_cosine():
    c = OPT.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(OPT.schedule(c, jnp.int32(5))) == pytest.approx(0.5)
    assert float(OPT.schedule(c, jnp.int32(10))) == pytest.approx(1.0)
    assert float(OPT.schedule(c, jnp.int32(110))) == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------- data
def test_data_deterministic_and_partitioned():
    cfg = DATA.DataConfig(vocab=100, seq_len=16, global_batch=8, seed=1)
    g = DATA.global_batch(cfg, step=3)
    h0 = DATA.host_batch(cfg, 3, host_id=0, num_hosts=4)
    h2 = DATA.host_batch(cfg, 3, host_id=2, num_hosts=4)
    assert (g["tokens"][:2] == h0["tokens"]).all()
    assert (g["tokens"][4:6] == h2["tokens"]).all()
    # same step twice -> identical (pure function of step)
    assert (DATA.global_batch(cfg, 3)["tokens"] == g["tokens"]).all()
    assert not (DATA.global_batch(cfg, 4)["tokens"] == g["tokens"]).all()
    # labels shifted
    assert (g["labels"][:, :-1] == g["tokens"][:, 1:]).all()


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    state = dict(params=dict(a=jnp.arange(6).reshape(2, 3).astype(jnp.float32)),
                 opt=dict(step=jnp.int32(7)), step=jnp.int32(7))
    d = str(tmp_path)
    CKPT.save(d, 7, state)
    CKPT.save(d, 14, state)
    step, restored = CKPT.restore(d)
    assert step == 14
    np.testing.assert_array_equal(np.asarray(restored["params"]["a"]),
                                  np.asarray(state["params"]["a"]))
    CKPT.save(d, 21, state)
    CKPT.save(d, 28, state)
    CKPT.gc_old(d, keep=2)
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_00000021", "step_00000028"]


def test_checkpoint_ignores_incomplete_tmp(tmp_path):
    d = str(tmp_path)
    state = dict(a=jnp.zeros(3))
    CKPT.save(d, 5, state)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))  # simulated crash
    step, _ = CKPT.restore(d)
    assert step == 5


# ---------------------------------------------------------------- fault loop
def _tiny_driver(tmp_path, inject_at=None):
    cfg = get_arch("qwen2.5-3b").reduced()
    opt_cfg = OPT.AdamWConfig(lr=1e-3, total_steps=20, warmup_steps=2)
    dcfg = DATA.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=0)
    step_fn = jax.jit(TS.make_train_step(cfg, opt_cfg), donate_argnums=(0,))

    injected = {"done": False}

    def injector(step):
        if inject_at is not None and step == inject_at and not injected["done"]:
            injected["done"] = True
            raise RuntimeError("boom")

    losses = {}
    state = FAULT.run_loop(
        init_state_fn=lambda: TS.init_train_state(cfg, jax.random.PRNGKey(0))[0],
        train_step=step_fn,
        batch_fn=lambda s: {k: jnp.asarray(v)
                            for k, v in DATA.global_batch(dcfg, s).items()},
        total_steps=12,
        fault=FAULT.FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=4),
        on_metrics=lambda s, m: losses.__setitem__(s, float(m["loss"])),
        failure_injector=injector)
    return state, losses


def test_fault_recovery_reproduces_failure_free_run(tmp_path):
    s_clean, l_clean = _tiny_driver(tmp_path / "clean")
    s_fail, l_fail = _tiny_driver(tmp_path / "fail", inject_at=6)
    # deterministic replay: same final params bit-for-bit
    for k in s_clean["params"]:
        np.testing.assert_array_equal(np.asarray(s_clean["params"][k]),
                                      np.asarray(s_fail["params"][k]))
    assert l_clean[12] == pytest.approx(l_fail[12])


# ---------------------------------------------------------------- compression
def test_error_feedback_invariant():
    rng = np.random.default_rng(0)
    g = dict(w=jnp.asarray(rng.standard_normal((32, 32)), jnp.float32))
    out1, ef1 = COMP.compress_decompress(g, None)
    # compressed + residual == original (exact bookkeeping)
    np.testing.assert_allclose(np.asarray(out1["w"]) + np.asarray(ef1["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    # second round folds the residual back in
    out2, ef2 = COMP.compress_decompress(g, ef1)
    np.testing.assert_allclose(
        np.asarray(out2["w"]) + np.asarray(ef2["w"]),
        np.asarray(g["w"]) + np.asarray(ef1["w"]), atol=1e-6)


def test_compressed_training_converges_similarly(tmp_path):
    cfg = get_arch("qwen2.5-3b").reduced()
    opt_cfg = OPT.AdamWConfig(lr=1e-3, total_steps=15, warmup_steps=2)
    dcfg = DATA.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=0)

    def run(compress):
        state, _ = TS.init_train_state(cfg, jax.random.PRNGKey(0),
                                       compress_grads=compress)
        step = jax.jit(TS.make_train_step(cfg, opt_cfg, compress_grads=compress),
                       donate_argnums=(0,))
        loss = None
        for s in range(10):
            batch = {k: jnp.asarray(v) for k, v in DATA.global_batch(dcfg, s).items()}
            state, m = step(state, batch)
            loss = float(m["loss"])
        return loss

    base, comp = run(False), run(True)
    assert abs(base - comp) / base < 0.05  # int8+EF tracks f32 closely


# ---------------------------------------------------------------- engine
def test_engine_slot_reuse_and_completion():
    cfg = get_arch("qwen2.5-3b").reduced()
    params, _ = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, slots=2, max_seq=64, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    for rid in range(5):  # 5 requests through 2 slots -> reuse required
        eng.submit(Request(rid=rid, max_new=4,
                           prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32)))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) >= 5 for r in done)
    assert len(eng.free) == 2  # all slots returned to the pool
