"""EscherStore: init, reads, insertion cases 1-3, horizontal ops, overflow
chaining, error flags."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops
from repro.core.store import (
    EMPTY, ERR_CAPACITY, init_store, read_dense, read_sorted)


def build(data, max_card=8, max_edges=16, capacity=4096, granule=32):
    lists = np.full((len(data), max_card), EMPTY, np.int32)
    cards = np.array([len(x) for x in data], np.int32)
    for i, x in enumerate(data):
        lists[i, : len(x)] = sorted(x)
    return init_store(jnp.asarray(lists), jnp.asarray(cards),
                      max_edges=max_edges, capacity=capacity, granule=granule)


DATA = [[0, 1, 2], [1, 3], [2, 3, 4, 5], [0, 5], [4, 6], [1, 2, 6]]


def rows_to_sets(rows):
    rows = np.asarray(rows)
    return [set(r[r != EMPTY].tolist()) for r in rows]


def test_init_and_read():
    st = build(DATA)
    got = rows_to_sets(read_dense(st, jnp.arange(6)))
    assert got == [set(x) for x in DATA]
    # block layout: paper granule sizing
    assert int(st.free_ptr) == 6 * 32
    # sorted read pads EMPTY to the end
    rs = np.asarray(read_sorted(st, jnp.arange(2)))
    assert rs[0].tolist()[:3] == [0, 1, 2]
    assert (rs[0][3:] == EMPTY).all()


def test_case1_reuse_same_block():
    st = build(DATA)
    st = ops.delete_hyperedges(st, jnp.array([1, 4]), jnp.ones(2, bool))
    free_before = int(st.free_ptr)
    nl = np.full((2, 8), EMPTY, np.int32)
    nl[0, :2] = [7, 8]
    nl[1, :3] = [9, 10, 11]
    st, ranks = ops.insert_hyperedges(st, jnp.asarray(nl), jnp.array([2, 3]),
                                      jnp.ones(2, bool))
    assert sorted(np.asarray(ranks).tolist()) == [1, 4]  # ID reuse
    assert int(st.free_ptr) == free_before               # NO new allocation
    got = rows_to_sets(read_dense(st, ranks))
    assert got == [{7, 8}, {9, 10, 11}]


def test_case2_overflow_chaining():
    st = build(DATA, max_card=48)
    st = ops.delete_hyperedges(st, jnp.array([2]), jnp.ones(1, bool))
    big = list(range(100, 140))                           # 40 > 31 usable
    nl = np.full((1, 48), EMPTY, np.int32)
    nl[0, :40] = big
    st, ranks = ops.insert_hyperedges(st, jnp.asarray(nl), jnp.array([40]),
                                      jnp.ones(1, bool))
    assert int(ranks[0]) == 2
    assert int(st.error) == 0
    assert rows_to_sets(read_dense(st, ranks)) == [set(big)]
    # chained: node has an overflow block
    from repro.core import blockmgr as bm
    idx = int(bm.cbt_index(jnp.int32(2), st.mgr.height))
    assert int(st.mgr.addr1[idx]) >= 0


def test_case3_fresh_allocation():
    st = build(DATA)
    nl = np.full((3, 8), EMPTY, np.int32)
    for i in range(3):
        nl[i, :2] = [20 + i, 30 + i]
    st, ranks = ops.insert_hyperedges(st, jnp.asarray(nl),
                                      jnp.full(3, 2, np.int32), jnp.ones(3, bool))
    assert sorted(np.asarray(ranks).tolist()) == [6, 7, 8]  # fresh ranks
    got = rows_to_sets(read_dense(st, ranks))
    assert got == [{20, 30}, {21, 31}, {22, 32}]


def test_capacity_overflow_sets_error_flag():
    st = build(DATA, capacity=224)  # exactly 6*32+32: one insert fits, two don't
    nl = np.full((2, 8), EMPTY, np.int32)
    nl[:, :2] = [[50, 51], [52, 53]]
    st, _ = ops.insert_hyperedges(st, jnp.asarray(nl), jnp.full(2, 2, np.int32),
                                  jnp.ones(2, bool))
    assert int(st.error) == ERR_CAPACITY


def test_horizontal_grouped_updates():
    st = build(DATA)
    # 3 updates on the same hyperedge + 1 on another, single batch
    st = ops.apply_vertex_updates(
        st,
        jnp.array([0, 0, 0, 2]),
        jnp.array([7, 8, 1, 9]),
        jnp.array([True, True, False, True]),
        jnp.ones(4, bool),
    )
    got = rows_to_sets(read_dense(st, jnp.array([0, 2])))
    assert got == [{0, 2, 7, 8}, {2, 3, 4, 5, 9}]


def test_horizontal_duplicate_insert_is_noop():
    st = build(DATA)
    st2 = ops.apply_vertex_updates(st, jnp.array([0]), jnp.array([1]),
                                   jnp.array([True]), jnp.ones(1, bool))
    assert rows_to_sets(read_dense(st2, jnp.array([0])))[0] == {0, 1, 2}


def test_delete_missing_vertex_is_noop():
    st = build(DATA)
    st2 = ops.apply_vertex_updates(st, jnp.array([1]), jnp.array([9]),
                                   jnp.array([False]), jnp.ones(1, bool))
    assert rows_to_sets(read_dense(st2, jnp.array([1])))[0] == {1, 3}
