"""Incident-vertex triads (StatHyper types 1/2/3) vs brute force."""
from itertools import combinations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hypergraph as H
from repro.core.vertex_triads import count_vertex_triads
from conftest import rand_hyperedges


def brute(edges, V):
    sets = [set(e) for e in edges]
    t1 = t2 = t3 = 0
    for u, v, w in combinations(range(V), 3):
        p = [sum(1 for s in sets if a in s and b in s)
             for a, b in ((u, v), (v, w), (u, w))]
        nuvw = sum(1 for s in sets if u in s and v in s and w in s)
        con = sum(1 for x in p if x > 0)
        if con == 3:
            if nuvw > 0:
                t1 += 1
            else:
                t3 += 1
        elif con in (1, 2):
            t2 += 1
    return t1, t2, t3


@pytest.mark.parametrize("seed,n,v", [(3, 15, 10), (5, 20, 12)])
def test_vertex_triads_match_brute(seed, n, v):
    rng = np.random.default_rng(seed)
    edges = rand_hyperedges(rng, n, v)
    hg = H.from_lists(edges, num_vertices=v + 4)
    R = hg.num_vertices
    vids = jnp.arange(R, dtype=jnp.int32)
    mask = vids < v
    got = tuple(np.asarray(count_vertex_triads(
        hg, vids, mask, v, max_nb=16, chunk=64)).tolist())
    assert got == brute(edges, v)


def test_type3_requires_three_distinct_hyperedges():
    # {0,1},{1,2},{0,2}: closed triple, no single covering edge -> type 3
    hg = H.from_lists([[0, 1], [1, 2], [0, 2]], num_vertices=4)
    vids = jnp.arange(hg.num_vertices, dtype=jnp.int32)
    got = np.asarray(count_vertex_triads(hg, vids, vids < 3, 3, max_nb=8, chunk=16))
    assert got.tolist() == [0, 0, 1]
    # add covering edge -> becomes type 1
    hg2 = H.from_lists([[0, 1], [1, 2], [0, 2], [0, 1, 2]], num_vertices=4)
    got2 = np.asarray(count_vertex_triads(hg2, vids, vids < 3, 3, max_nb=8, chunk=16))
    assert got2.tolist() == [1, 0, 0]
