"""End-to-end histogram parity across all three kernel backends
(pallas / xla / bitset) for every counting driver: static counts, an
Alg. 3 churn batch, and the streaming scan — plus the sharded twins.

This is the contract the fused rewiring must preserve: the backend is an
implementation detail, the triad histograms are bit-identical (the
consumers only feed duplicate-free sorted rows, so the set-semantic fused
stats agree with the historical unfused sequence exactly).

Graphs are tiny on purpose: the pallas backend runs in interpret mode on
CPU, which is orders of magnitude slower than compiled Mosaic — the point
here is path coverage, not throughput (fig19 measures that).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import motifs
from repro.core import stream as S
from repro.core import triads as T
from repro.core import update as U
from repro.core import vertex_triads as VT
from repro.distributed import triads as DT
from repro.hypergraph import generators as GEN

BACKENDS = ("xla", "pallas", "bitset")
V, MAXC, MAXD, MAXNB, MAXR, CHUNK = 16, 8, 16, 16, 63, 64
EMPTY_PAD = jnp.iinfo(jnp.int32).max


def _hg(n_edges=24, seed=0):
    edges = GEN.random_hypergraph(n_edges, V, profile="coauth", max_card=6,
                                  seed=seed, skew=0.3)
    return H.from_lists(edges, num_vertices=V, max_edges=4 * n_edges,
                        max_card=MAXC, slack=4.0)


def _assert_all_equal(results):
    ref = np.asarray(results["xla"])
    for backend, got in results.items():
        assert (np.asarray(got) == ref).all(), (
            f"backend {backend} diverges: {np.asarray(got)} vs {ref}")


@pytest.mark.parametrize("temporal", [False, True])
def test_static_edge_parity(temporal):
    hg = _hg()
    reg, m = T.all_live_region(hg, MAXR)
    times = jnp.arange(hg.n_edge_slots, dtype=jnp.int32) * 7 + 1
    _assert_all_equal({
        b: T.count_triads(hg, reg, m, max_deg=MAXD, chunk=CHUNK,
                          temporal=temporal, times=times,
                          window=40 if temporal else None, backend=b)
        for b in BACKENDS})


def test_static_vertex_parity():
    hg = _hg()
    vids = jnp.arange(V, dtype=jnp.int32)
    mask = jnp.ones(V, bool)
    _assert_all_equal({
        b: VT.count_vertex_triads(hg, vids, mask, V, max_nb=MAXNB,
                                  chunk=CHUNK, backend=b)
        for b in BACKENDS})


def test_churn_parity():
    results = {}
    for b in BACKENDS:
        hg = _hg()
        reg, m = T.all_live_region(hg, MAXR)
        counts = T.count_triads(hg, reg, m, max_deg=MAXD, chunk=CHUNK,
                                backend=b)
        hg2, counts2, _ = U.update_triad_counts(
            hg, counts,
            jnp.array([1, 3]), jnp.array([True, True]),
            jnp.array([[0, 2, 5, EMPTY_PAD, EMPTY_PAD, EMPTY_PAD, EMPTY_PAD,
                        EMPTY_PAD]], jnp.int32),
            jnp.array([3]), jnp.array([True]),
            max_deg=MAXD, max_region=MAXR, chunk=CHUNK, backend=b)
        results[b] = counts2
    _assert_all_equal(results)


def test_stream_parity():
    events = GEN.event_stream(20, V, profile="coauth", insert_frac=0.7,
                              seed=2, max_card=5, max_dt=2)
    steps = S.plan_steps(events, 6)
    results = {}
    for b in BACKENDS:
        hg = H.from_lists([], num_vertices=V, max_edges=64, max_card=MAXC,
                          max_vdeg=32, min_capacity=4096)
        log = S.log_from_events(events, max_card=MAXC)
        st = S.make_stream(hg, log, jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
        st = S.run_stream(st, n_steps=steps, batch=6, mode="edge",
                          max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
                          backend=b)
        assert int(st.error) == 0
        results[b] = st.counts
    _assert_all_equal(results)


def test_grown_and_compacted_store_parity():
    """Backend parity must hold on *non-initial* layouts too (ISSUE 5): a
    store that chained (Case-2 overflow), then grew (2x capacity, +1 tree
    level on both stores — the bitset backend's n_bits universe resizes),
    then compacted (chains folded, post-chain layout).  Both triad families
    at every stage, all three backends."""
    from repro.core import elastic as E

    edges = GEN.random_hypergraph(20, V, profile="coauth", max_card=6,
                                  seed=9, skew=0.3)
    hg = H.from_lists(edges, num_vertices=V, max_edges=32, max_card=16,
                      granule=8, slack=2.0)
    hg = H.delete_hyperedges(hg, jnp.array([2, 5]), jnp.ones(2, bool))
    nl = np.full((2, 16), EMPTY_PAD, np.int32)
    nl[0, :12] = np.arange(12)                 # card 12 > 7 usable: chains
    nl[1, :11] = np.arange(4, 15)
    hg, _ = H.insert_hyperedges(hg, jnp.asarray(nl),
                                jnp.array([12, 11], np.int32),
                                jnp.ones(2, bool))
    assert int(hg.h2v.error) == 0
    assert int(jnp.sum((hg.h2v.mgr.addr1 >= 0)
                       & (hg.h2v.mgr.present == 1))) > 0   # chained layout

    grown = E.grow_hypergraph(
        hg, h2v_capacity=2 * hg.h2v.capacity, h2v_levels=1,
        v2h_capacity=2 * hg.v2h.capacity, v2h_levels=1)
    compacted = E.compact_hypergraph(grown)
    assert int(compacted.h2v.free_ptr) <= int(grown.h2v.free_ptr)

    for layout in (hg, grown, compacted):
        reg, m = T.all_live_region(layout, MAXR)
        _assert_all_equal({
            b: T.count_triads(layout, reg, m, max_deg=MAXD, chunk=CHUNK,
                              backend=b)
            for b in BACKENDS})
        nv = layout.num_vertices
        vids = jnp.arange(nv, dtype=jnp.int32)
        vmask = jnp.ones(nv, bool)
        _assert_all_equal({
            b: VT.count_vertex_triads(layout, vids, vmask, nv,
                                      max_nb=MAXNB, chunk=CHUNK, backend=b)
            for b in BACKENDS})
    # growth/compaction never change the counts themselves
    reg, m = T.all_live_region(hg, MAXR)
    ref = T.count_triads(hg, reg, m, max_deg=MAXD, chunk=CHUNK,
                         backend="xla")
    for layout in (grown, compacted):
        reg, m = T.all_live_region(layout, MAXR)
        got = T.count_triads(layout, reg, m, max_deg=MAXD, chunk=CHUNK,
                             backend="xla")
        assert (np.asarray(got) == np.asarray(ref)).all()


def test_sharded_parity():
    """Sharded twins agree with the single-device path for every backend on
    whatever mesh this host offers (CI's distributed job widens it to 8)."""
    mesh = DT.count_mesh(min(8, len(jax.devices())))
    hg = _hg()
    reg, m = T.all_live_region(hg, MAXR)
    vids = jnp.arange(V, dtype=jnp.int32)
    vmask = jnp.ones(V, bool)
    for b in BACKENDS:
        edge_ref = T.count_triads(hg, reg, m, max_deg=MAXD, chunk=CHUNK,
                                  backend=b)
        edge_got = DT.count_triads_sharded(hg, reg, m, mesh=mesh,
                                           max_deg=MAXD, chunk=CHUNK,
                                           backend=b)
        assert (np.asarray(edge_got) == np.asarray(edge_ref)).all(), b
        vert_ref = VT.count_vertex_triads(hg, vids, vmask, V, max_nb=MAXNB,
                                          chunk=CHUNK, backend=b)
        vert_got = DT.count_vertex_triads_sharded(
            hg, vids, vmask, V, mesh=mesh, max_nb=MAXNB, chunk=CHUNK,
            backend=b)
        assert (np.asarray(vert_got) == np.asarray(vert_ref)).all(), b


def test_auto_backend_matches_explicit(monkeypatch):
    """backend=None (auto-selection) must be histogram-identical to every
    explicit choice — selection is a perf knob, never a semantics knob.

    At test sizes the cost rule never flips (c=8 < PACK_COST), so force it:
    with PACK_COST=0 the auto path genuinely resolves to bitset and the
    histogram must still match xla.  A distinct ``chunk`` guards against
    reusing the jit trace cached under the un-patched rule."""
    from repro.kernels import ops as kops

    hg = _hg(seed=5)
    reg, m = T.all_live_region(hg, MAXR)
    ref = T.count_triads(hg, reg, m, max_deg=MAXD, chunk=48, backend="xla")
    monkeypatch.setattr(kops, "PACK_COST", 0)
    assert kops.resolve_backend(None, c=MAXC, n_bits=V) == "bitset"
    auto = T.count_triads(hg, reg, m, max_deg=MAXD, chunk=48)
    assert (np.asarray(auto) == np.asarray(ref)).all()
