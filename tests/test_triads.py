"""Hyperedge-based triad counting vs brute-force enumeration (+ the
26-class table invariant)."""
from itertools import combinations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hypergraph as H
from repro.core import motifs, triads
from conftest import rand_hyperedges


def brute_hist(edges):
    hist = np.zeros(26, np.int64)
    sets = [set(e) for e in edges]
    for i, j, k in combinations(range(len(edges)), 3):
        a, b, c = sets[i], sets[j], sets[k]
        if (len(a & b) > 0) + (len(a & c) > 0) + (len(b & c) > 0) < 2:
            continue
        code = int(motifs.region_code(
            np.int32(len(a)), np.int32(len(b)), np.int32(len(c)),
            np.int32(len(a & b)), np.int32(len(a & c)), np.int32(len(b & c)),
            np.int32(len(a & b & c))))
        cls = motifs.CLASS_ID[motifs.CANON[code]]
        assert cls >= 0
        hist[cls] += 1
    return hist


def test_exactly_26_classes():
    assert motifs.NUM_CLASSES == 26
    assert int(motifs.CLASS_CLOSED.sum()) == 20  # 20 closed + 6 open


@pytest.mark.parametrize("seed,n,v", [(1, 15, 10), (2, 25, 15), (3, 30, 12)])
def test_count_matches_brute_force(seed, n, v):
    rng = np.random.default_rng(seed)
    edges = rand_hyperedges(rng, n, v)
    hg = H.from_lists(edges, max_edges=64)
    ranks = jnp.arange(64, dtype=jnp.int32)
    mask = ranks < len(edges)
    got = np.asarray(triads.count_triads(hg, ranks, mask, max_deg=48, chunk=256))
    exp = brute_hist(edges)
    assert (got == exp).all(), (got.tolist(), exp.tolist())


def test_region_restriction_counts_subset_only():
    rng = np.random.default_rng(9)
    edges = rand_hyperedges(rng, 20, 10)
    hg = H.from_lists(edges, max_edges=64)
    sub = list(range(0, len(edges), 2))
    ranks = jnp.asarray(np.pad(sub, (0, 64 - len(sub))).astype(np.int32))
    mask = jnp.arange(64) < len(sub)
    got = np.asarray(triads.count_triads(hg, ranks, mask, max_deg=48, chunk=256))
    exp = brute_hist([edges[i] for i in sub])
    assert (got == exp).all()


def test_pallas_backend_matches_xla_backend():
    rng = np.random.default_rng(4)
    edges = rand_hyperedges(rng, 12, 8)
    hg = H.from_lists(edges, max_edges=32)
    ranks = jnp.arange(32, dtype=jnp.int32)
    mask = ranks < len(edges)
    a = triads.count_triads(hg, ranks, mask, max_deg=32, chunk=128, backend="xla")
    b = triads.count_triads(hg, ranks, mask, max_deg=32, chunk=128, backend="pallas")
    assert (np.asarray(a) == np.asarray(b)).all()
