"""HLO parser: exact flop/byte/collective extraction incl. loop trip counts.
Multi-device cases run in a subprocess so the 8-device override never leaks
into the test process (the suite must see 1 CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.splitlines()[-1])


def test_scan_matmul_flops_and_collectives_exact():
    res = run_sub(textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline import hlo_parse as HP
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        L, D, F, B = 5, 64, 256, 16
        def step(params, x):
            def body(x, w):
                return (x @ w["a"]) @ w["b"], None
            x, _ = jax.lax.scan(body, x, params)
            return jnp.sum(x)
        params = dict(a=jax.ShapeDtypeStruct((L, D, F), jnp.float32),
                      b=jax.ShapeDtypeStruct((L, F, D), jnp.float32))
        x = jax.ShapeDtypeStruct((B, D), jnp.float32)
        psh = dict(a=NamedSharding(mesh, P(None, None, "model")),
                   b=NamedSharding(mesh, P(None, "model", None)))
        with mesh:
            comp = jax.jit(step, in_shardings=(psh, NamedSharding(mesh, P("data", None)))) \
                .lower(params, x).compile()
        c = HP.parse_hlo(comp.as_text())
        print(json.dumps(dict(flops=c.flops, coll=c.coll_bytes, ops=c.coll_ops)))
    """))
    # per-device: L × (2·8·64·64 + 2·8·64·64) with B/2=8, F/4=64 local
    assert res["flops"] == 5 * (2 * 8 * 64 * 64 + 2 * 8 * 64 * 64)
    # TP all-reduce inside the loop: 5 × (8·64·4B) + scalar loss reduce
    assert res["coll"]["all-reduce"] == 5 * 8 * 64 * 4 + 4
    assert res["ops"]["all-reduce"] == 6


def test_roofline_terms_and_dominance():
    from repro.roofline import analysis as RA
    from repro.roofline.hlo_parse import HloCost
    hc = HloCost(flops=197e12, bytes=819e9 * 2, coll_bytes={"all-reduce": 50e9},
                 coll_ops={})
    rl = RA.roofline_from_hlo(hc, chips=256, model_flops=197e12 * 256)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(2.0)
    assert rl.collective_s == pytest.approx(1.0)
    assert rl.dominant == "memory"
    assert rl.roofline_fraction == pytest.approx(0.5)


def test_small_mesh_dryrun_train_and_decode():
    """Sharding rules partition a real (reduced) model on an 8-device mesh."""
    res = run_sub(textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_arch
        from repro.distributed import sharding as SH
        from repro.models import api
        from repro.train import optimizer as OPT, train_step as TS

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_arch("qwen2.5-3b").reduced()
        cell = {}
        def fn(key):
            p, s = api.init_params(cfg, key, jnp.float32)
            cell["specs"] = s
            return dict(params=p, opt=OPT.init_state(p), step=jnp.zeros((), jnp.int32))
        state = jax.eval_shape(fn, jax.random.PRNGKey(0))
        sh = TS.state_shardings(cell["specs"], state, "tp", mesh)
        batch = dict(tokens=jax.ShapeDtypeStruct((4, 32), jnp.int32),
                     labels=jax.ShapeDtypeStruct((4, 32), jnp.int32))
        bsh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
        step = TS.make_train_step(cfg, OPT.AdamWConfig())
        with mesh:
            comp = jax.jit(step, in_shardings=(sh, bsh), out_shardings=(sh, None)) \
                .lower(state, batch).compile()
        txt = comp.as_text()
        print(json.dumps(dict(ok=True, has_allreduce=("all-reduce" in txt))))
    """))
    assert res["ok"] and res["has_allreduce"]
