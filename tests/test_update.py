"""Alg. 3 correctness: delta update == full static recount, all three triad
families, across multiple churn batches."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import triads as T
from repro.core import update as U
from repro.core.store import EMPTY
from repro.core.vertex_triads import count_vertex_triads
from conftest import rand_hyperedges

MAXD, MAXR, MAXC = 64, 127, 8
V = 18


def _batch(rng, hg, n_del, n_ins):
    present = np.asarray(hg.h2v.mgr.present)
    hid = np.asarray(hg.h2v.mgr.hid)
    live = hid[present == 1]
    dels = rng.choice(live, size=min(n_del, len(live)), replace=False).astype(np.int32)
    newe = rand_hyperedges(rng, n_ins, V)
    nl = np.full((n_ins, MAXC), EMPTY, np.int32)
    nc = np.zeros(n_ins, np.int32)
    for i, e in enumerate(newe):
        nl[i, : len(e)] = sorted(e)
        nc[i] = len(e)
    return (jnp.asarray(dels), jnp.ones(len(dels), bool),
            jnp.asarray(nl), jnp.asarray(nc), jnp.ones(n_ins, bool))


def test_hyperedge_update_equals_recount():
    rng = np.random.default_rng(11)
    hg = H.from_lists(rand_hyperedges(rng, 25, V), num_vertices=V,
                      max_edges=128, max_card=MAXC)
    counts = BL.mochy_static(hg, max_deg=MAXD, max_region=MAXR, chunk=256)
    for _ in range(3):
        d, dm, nl, nc, im = _batch(rng, hg, 5, 6)
        hg, counts, _ = U.update_triad_counts(
            hg, counts, d, dm, nl, nc, im,
            max_deg=MAXD, max_region=MAXR, chunk=256)
        ref = BL.mochy_static(hg, max_deg=MAXD, max_region=MAXR, chunk=256)
        assert (np.asarray(counts) == np.asarray(ref)).all()


def test_temporal_update_equals_recount():
    rng = np.random.default_rng(21)
    edges = rand_hyperedges(rng, 20, V)
    hg = H.from_lists(edges, num_vertices=V, max_edges=128, max_card=MAXC)
    times = jnp.asarray(
        np.pad(rng.permutation(500)[:len(edges)].astype(np.int32),
               (0, hg.n_edge_slots - len(edges))))
    W = 200
    counts = BL.thyme_static(hg, times, W, max_deg=MAXD, max_region=MAXR, chunk=256)
    t_next = 1000
    for _ in range(2):
        d, dm, nl, nc, im = _batch(rng, hg, 4, 5)
        ins_t = jnp.asarray(np.arange(t_next, t_next + nl.shape[0]).astype(np.int32))
        t_next += 100
        hg, counts, times = U.update_triad_counts(
            hg, counts, d, dm, nl, nc, im,
            max_deg=MAXD, max_region=MAXR, chunk=256,
            temporal=True, times=times, ins_times=ins_t, window=W)
        ref = BL.thyme_static(hg, times, W, max_deg=MAXD, max_region=MAXR, chunk=256)
        assert (np.asarray(counts) == np.asarray(ref)).all()


def test_vertex_update_equals_recount():
    rng = np.random.default_rng(31)
    hg = H.from_lists(rand_hyperedges(rng, 18, V), num_vertices=V,
                      max_edges=128, max_card=MAXC)
    counts = BL.stathyper_static(hg, V, max_nb=24, max_region=V, chunk=128)
    for _ in range(2):
        d, dm, nl, nc, im = _batch(rng, hg, 3, 4)
        hg, counts = U.update_vertex_triad_counts(
            hg, counts, V, d, dm, nl, nc, im,
            max_nb=24, max_region=64, chunk=128)
        ref = BL.stathyper_static(hg, V, max_nb=24, max_region=V, chunk=128)
        assert (np.asarray(counts) == np.asarray(ref)).all()


def test_affected_region_covers_two_hops():
    hg = H.from_lists([[0, 1], [1, 2], [2, 3], [3, 4], [4, 5]],
                      num_vertices=8, max_edges=32)
    seeds = jnp.array([0], jnp.int32)
    reg, m = U.affected_edges(hg, seeds, jnp.ones(1, bool),
                              max_deg=16, max_region=31)
    got = set(np.asarray(reg)[np.asarray(m)].tolist())
    assert got == {0, 1, 2}  # edge 0 + 1-hop (1) + 2-hop (2)


def test_delta_update_equals_recount():
    """§Perf E2: containing-triple delta == full recount (adequate max_deg)."""
    rng = np.random.default_rng(77)
    hg = H.from_lists(rand_hyperedges(rng, 22, V), num_vertices=V,
                      max_edges=128, max_card=MAXC)
    counts = BL.mochy_static(hg, max_deg=MAXD, max_region=MAXR, chunk=256)
    for _ in range(2):
        d, dm, nl, nc, im = _batch(rng, hg, 4, 5)
        hg, counts, _ = U.update_triad_counts_delta(
            hg, counts, d, dm, nl, nc, im, max_deg=MAXD, chunk=256)
        ref = BL.mochy_static(hg, max_deg=MAXD, max_region=MAXR, chunk=256)
        assert (np.asarray(counts) == np.asarray(ref)).all()


def test_bucketed_auto_update_equals_recount():
    """§Perf E1: bucketed region specialisation is exact."""
    rng = np.random.default_rng(88)
    hg = H.from_lists(rand_hyperedges(rng, 20, V), num_vertices=V,
                      max_edges=128, max_card=MAXC)
    counts = BL.mochy_static(hg, max_deg=MAXD, max_region=MAXR, chunk=256)
    d, dm, nl, nc, im = _batch(rng, hg, 3, 4)
    hg, counts, _ = U.update_triad_counts_auto(
        hg, counts, d, dm, nl, nc, im,
        max_deg=MAXD, max_region=MAXR, chunk=256, min_region=32)
    ref = BL.mochy_static(hg, max_deg=MAXD, max_region=MAXR, chunk=256)
    assert (np.asarray(counts) == np.asarray(ref)).all()


def test_delta_update_temporal_equals_recount():
    rng = np.random.default_rng(99)
    edges = rand_hyperedges(rng, 18, V)
    hg = H.from_lists(edges, num_vertices=V, max_edges=128, max_card=MAXC)
    times = jnp.asarray(
        np.pad(rng.permutation(400)[:len(edges)].astype(np.int32),
               (0, hg.n_edge_slots - len(edges))))
    W = 150
    counts = BL.thyme_static(hg, times, W, max_deg=MAXD, max_region=MAXR, chunk=256)
    d, dm, nl, nc, im = _batch(rng, hg, 3, 4)
    ins_t = jnp.asarray(np.arange(500, 500 + nl.shape[0]).astype(np.int32))
    hg, counts, times = U.update_triad_counts_delta(
        hg, counts, d, dm, nl, nc, im, max_deg=MAXD, chunk=256,
        temporal=True, times=times, ins_times=ins_t, window=W)
    ref = BL.thyme_static(hg, times, W, max_deg=MAXD, max_region=MAXR, chunk=256)
    assert (np.asarray(counts) == np.asarray(ref)).all()
