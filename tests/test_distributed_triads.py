"""Sharded triad engine (distributed/triads.py): sharded == single-device,
bit-identical, for all three counting families — static counts, an Alg. 3
churn batch, and a short event stream (DESIGN.md §3.2/§6).

The mesh spans ``min(8, len(jax.devices()))`` host devices: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the real 8-way
check (the CI distributed job does exactly that — see
``test_ci_mesh_is_really_8_wide``); on a plain single-device host the same
assertions run on a 1-device mesh, so the engine code path is always
exercised by the tier-1 suite.

Everything here shares one hypergraph / one (bounds, chunk) signature per
family to stay compile-bound-friendly, mirroring test_stream.py.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import motifs
from repro.core import stream as S
from repro.core import triads as T
from repro.core import update as U
from repro.core import vertex_triads as VT
from repro.distributed import triads as DT
from repro.hypergraph import generators as GEN

V, MAXC, MAXD, MAXR, CHUNK = 18, 8, 32, 127, 256
N_SHARDS = min(8, len(jax.devices()))
MESH = DT.count_mesh(N_SHARDS)


def _hg(n_edges=40, seed=0):
    edges = GEN.random_hypergraph(n_edges, V, profile="coauth", max_card=6,
                                  seed=seed, skew=0.3)
    return H.from_lists(edges, num_vertices=V, max_edges=4 * n_edges,
                        max_card=MAXC, slack=4.0)


def test_ci_mesh_is_really_8_wide():
    """When the 8-device XLA flag is set (the CI distributed job), the mesh
    must actually be 8 wide — guards against the flag silently not applying
    and the parity tests degenerating to a 1-device run."""
    if "xla_force_host_platform_device_count=8" not in os.environ.get(
            "XLA_FLAGS", ""):
        pytest.skip("8-device host mesh not requested via XLA_FLAGS")
    assert len(jax.devices()) >= 8
    assert DT.shard_count(MESH) == 8


def test_static_edge_parity():
    hg = _hg()
    reg, m = T.all_live_region(hg, MAXR)
    ref = T.count_triads(hg, reg, m, max_deg=MAXD, chunk=CHUNK)
    got = DT.count_triads_sharded(hg, reg, m, mesh=MESH, max_deg=MAXD,
                                  chunk=CHUNK)
    assert (np.asarray(got) == np.asarray(ref)).all()
    assert int(ref.sum()) > 0


def test_static_edge_parity_any_shard_count():
    """Bit-identity holds for every shard count, not just the full mesh —
    the psum merge is pure int32 addition over a disjoint partition."""
    hg = _hg()
    reg, m = T.all_live_region(hg, MAXR)
    ref = T.count_triads(hg, reg, m, max_deg=MAXD, chunk=CHUNK)
    for d in {1, 2, N_SHARDS}:
        if d > N_SHARDS:
            continue
        got = DT.count_triads_sharded(hg, reg, m, mesh=DT.count_mesh(d),
                                      max_deg=MAXD, chunk=CHUNK)
        assert (np.asarray(got) == np.asarray(ref)).all(), f"devices={d}"


def test_static_temporal_parity():
    hg = _hg()
    rng = np.random.default_rng(1)
    times = jnp.asarray(rng.integers(0, 1000, hg.n_edge_slots).astype(np.int32))
    reg, m = T.all_live_region(hg, MAXR)
    kw = dict(max_deg=MAXD, chunk=CHUNK, temporal=True, times=times,
              window=200)
    ref = T.count_triads(hg, reg, m, **kw)
    got = DT.count_triads_sharded(hg, reg, m, mesh=MESH, **kw)
    assert (np.asarray(got) == np.asarray(ref)).all()
    assert int(ref.sum()) > 0


def test_static_vertex_parity():
    hg = _hg()
    vids = jnp.arange(64, dtype=jnp.int32)
    vm = vids < V
    ref = VT.count_vertex_triads(hg, vids, vm, V, max_nb=32, chunk=128)
    got = DT.count_vertex_triads_sharded(hg, vids, vm, V, mesh=MESH,
                                         max_nb=32, chunk=128)
    assert (np.asarray(got) == np.asarray(ref)).all()
    assert int(ref.sum()) > 0


def _churn_batch(hg, n_changes=10, seed=3):
    present = np.asarray(hg.h2v.mgr.present)
    live = np.asarray(hg.h2v.mgr.hid)[present == 1]
    dels, ins = GEN.churn_batch(live, n_changes, 0.5, V, MAXC, seed=seed,
                                card_cap=6)
    nl, nc = GEN.pack_lists(ins, MAXC)
    return (jnp.asarray(dels), jnp.ones(len(dels), bool), jnp.asarray(nl),
            jnp.asarray(nc), jnp.ones(len(ins), bool))


def test_churn_step_parity():
    """One Alg. 3 batch through update_triad_counts, sharded vs not — the
    affected-region union pair list shards; the telescoped histogram (and
    the updated graph) must be bit-identical, and exact vs full recount."""
    hg = _hg()
    batch = _churn_batch(hg)
    c0 = BL.mochy_static(hg, max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    kw = dict(max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    hg_ref, ref, _ = U.update_triad_counts(hg, c0, *batch, **kw)
    hg_got, got, _ = U.update_triad_counts(hg, c0, *batch, mesh=MESH, **kw)
    assert (np.asarray(got) == np.asarray(ref)).all()
    assert int(hg_got.h2v.n_live) == int(hg_ref.h2v.n_live)
    recount = BL.mochy_static(hg_got, max_deg=MAXD, max_region=MAXR,
                              chunk=CHUNK)
    assert (np.asarray(got) == np.asarray(recount)).all()


def test_vertex_churn_step_parity():
    hg = _hg()
    batch = _churn_batch(hg, seed=5)
    c0 = BL.stathyper_static(hg, V, max_nb=32, max_region=V, chunk=128)
    kw = dict(max_nb=32, max_region=64, chunk=128)
    _, ref = U.update_vertex_triad_counts(hg, c0, V, *batch, **kw)
    _, got = U.update_vertex_triad_counts(hg, c0, V, *batch, mesh=MESH, **kw)
    assert (np.asarray(got) == np.asarray(ref)).all()


def _empty_hg():
    return H.from_lists([], num_vertices=V, max_edges=128, max_card=MAXC,
                        max_vdeg=64, min_capacity=4096)


def _run_stream(events, counts, mesh, **kw):
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(_empty_hg(), log, counts)
    n = S.plan_steps(events, 8)
    return S.run_stream(st, n_steps=n, batch=8, mesh=mesh, **kw)


def test_stream_edge_parity():
    """A short event stream through the scan driver with the sharded cores:
    identical counts/liveness to the single-device run, exact vs recount
    (parity with test_stream.py expectations)."""
    events = GEN.event_stream(24, V, seed=1, max_card=6, insert_frac=0.7)
    kw = dict(mode="edge", max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    zeros = jnp.zeros(motifs.NUM_CLASSES, jnp.int32)
    ref = _run_stream(events, zeros, None, **kw)
    got = _run_stream(events, zeros, MESH, **kw)
    assert int(got.error) == 0
    assert int(got.log.n_pending) == 0
    assert (np.asarray(got.counts) == np.asarray(ref.counts)).all()
    assert int(got.hg.h2v.n_live) == int(ref.hg.h2v.n_live)
    recount = BL.mochy_static(got.hg, max_deg=MAXD, max_region=MAXR,
                              chunk=CHUNK)
    assert (np.asarray(got.counts) == np.asarray(recount)).all()
    assert int(got.counts.sum()) > 0


def test_stream_temporal_parity():
    """Temporal family end to end: the δ-window counts maintained by the
    sharded cores match the single-device stream and a THyMe+ recount."""
    events = GEN.event_stream(24, V, seed=2, max_card=6, max_dt=4)
    W = 50
    kw = dict(mode="temporal", max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
              window=W)
    zeros = jnp.zeros(motifs.NUM_TEMPORAL, jnp.int32)
    ref = _run_stream(events, zeros, None, **kw)
    got = _run_stream(events, zeros, MESH, **kw)
    assert int(got.error) == 0
    assert (np.asarray(got.counts) == np.asarray(ref.counts)).all()
    recount = BL.thyme_static(got.hg, got.times, W, max_deg=MAXD,
                              max_region=MAXR, chunk=CHUNK)
    assert (np.asarray(got.counts) == np.asarray(recount)).all()


def test_stream_vertex_parity():
    events = GEN.event_stream(20, V, seed=4, max_card=6)
    kw = dict(mode="vertex", max_nb=32, max_region=64, chunk=128, v_total=V)
    zeros = jnp.zeros(3, jnp.int32)
    ref = _run_stream(events, zeros, None, **kw)
    got = _run_stream(events, zeros, MESH, **kw)
    assert int(got.error) == 0
    assert (np.asarray(got.counts) == np.asarray(ref.counts)).all()
    recount = BL.stathyper_static(got.hg, V, max_nb=32, max_region=V,
                                  chunk=128)
    assert (np.asarray(got.counts) == np.asarray(recount)).all()
