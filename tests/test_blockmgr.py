"""Block manager invariants: Eq.1 placement, search, avail propagation,
k-th-available descent."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockmgr as bm


@pytest.mark.parametrize("max_edges", [3, 10, 31, 100])
def test_cbt_index_is_inorder_bijection(max_edges):
    mgr = bm.build_manager(max_edges)
    n = mgr.n_slots
    ranks = jnp.arange(n, dtype=jnp.int32)
    idx = np.asarray(bm.cbt_index(ranks, mgr.height))
    # bijection into [1, n]
    assert sorted(idx.tolist()) == list(range(1, n + 1))
    # BST property: in-order traversal of hid is ascending
    assert (np.asarray(mgr.hid)[idx] == np.asarray(ranks)).all()


def test_search_matches_closed_form():
    mgr = bm.build_manager(40)
    ranks = jnp.arange(mgr.n_slots, dtype=jnp.int32)
    assert (bm.search(mgr, ranks) == bm.cbt_index(ranks, mgr.height)).all()


def test_delete_claim_avail_cycle():
    mgr = bm.build_manager(20)
    live = jnp.arange(15, dtype=jnp.int32)
    idx = bm.cbt_index(live, mgr.height)
    mgr = dataclasses.replace(mgr, present=mgr.present.at[idx].set(1))

    dels = jnp.array([3, 7, 11, 14], jnp.int32)
    mgr = bm.mark_delete(mgr, dels, jnp.ones(4, bool))
    assert int(mgr.root_avail) == 4
    # double delete is a no-op
    mgr2 = bm.mark_delete(mgr, dels[:2], jnp.ones(2, bool))
    assert int(mgr2.root_avail) == 4

    # k-th available returns the deleted ranks in ascending (in-order) order
    ks = jnp.arange(1, 5)
    nodes = bm.find_kth_available(mgr, ks)
    assert np.asarray(mgr.hid)[np.asarray(nodes)].tolist() == [3, 7, 11, 14]

    mgr = bm.claim_nodes(mgr, nodes[:2], jnp.ones(2, bool))
    assert int(mgr.root_avail) == 2
    nodes2 = bm.find_kth_available(mgr, jnp.arange(1, 3))
    assert np.asarray(mgr.hid)[np.asarray(nodes2)].tolist() == [11, 14]


def test_avail_counts_consistent_at_every_node():
    rng = np.random.default_rng(3)
    mgr = bm.build_manager(64)
    live = jnp.arange(60, dtype=jnp.int32)
    idx = bm.cbt_index(live, mgr.height)
    mgr = dataclasses.replace(mgr, present=mgr.present.at[idx].set(1))
    dels = jnp.asarray(rng.choice(60, size=17, replace=False).astype(np.int32))
    mgr = bm.mark_delete(mgr, dels, jnp.ones(17, bool))

    avail = np.asarray(mgr.avail)
    deleted = np.asarray(mgr.deleted)
    n = mgr.n_slots
    for i in range(n, 0, -1):  # bottom-up check: avail = deleted + children
        l = avail[2 * i] if 2 * i < len(avail) else 0
        r = avail[2 * i + 1] if 2 * i + 1 < len(avail) else 0
        assert avail[i] == deleted[i] + l + r, i
