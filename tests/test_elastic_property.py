"""Stateful differential fuzz suite for the elastic ESCHER store (ISSUE 5).

A state machine drives the full dynamic surface — hyperedge insert/delete,
incident-vertex insert/delete, elastic growth (capacity, rank space,
vertex universe) and compaction — against a pure-Python dict-of-sets
oracle.  After every rule the device store must agree with the oracle
*exactly*: ``read_dense``/``read_sorted`` contents of both mappings (h2v
and its v2h dual), live-rank sets, and a zero sticky error bitmask; at
checkpoints the device triad histogram must equal the host MoCHy recount
of the oracle.

Two drivers share one model:

  * ``hypothesis`` ``RuleBasedStateMachine`` (CI: requirements-dev.txt
    installs hypothesis) — shrinking finds minimal counterexamples;
  * a seeded random driver that runs everywhere hypothesis is absent, so
    the differential suite is never silently skipped.

Either way the suite runs >= 200 examples in the fast tier
(``ESCHER_FUZZ_EXAMPLES`` overrides).  Ops go through jitted wrappers with
fixed batch shapes: the jit cache persists across examples, so the compile
universe is bounded by the handful of (capacity, height) combinations the
growth rules can reach.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import elastic as E
from repro.core import hypergraph as H
from repro.core import triads as T
from repro.core.store import EMPTY, read_dense

try:
    import hypothesis
    from hypothesis import strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine, initialize, invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_EXAMPLES = int(os.environ.get("ESCHER_FUZZ_EXAMPLES", "200"))
STEPS = 8                 # rules per example

V0 = 15                   # initial vertex universe (v2h height 4)
MAXC = 6                  # hyperedge cardinality bound (h2v max_card)
MAXVD = 10                # vertex degree bound (v2h max_card)
GRANULE = 8
MAXD, MAXR, CHUNK = 16, 63, 64
MAX_LEVEL_GROWS = 1       # per store per example: bounds the jit universe

_jit_insert = jax.jit(H.insert_hyperedges)
_jit_delete = jax.jit(H.delete_hyperedges)
_jit_vupdate = jax.jit(H.apply_vertex_updates)
_ONE = jnp.ones(1, bool)


class ElasticModel:
    """The differential system under test: a two-way ESCHER hypergraph plus
    its dict-of-sets oracle, advanced in lockstep.  Rules are total — an
    op whose precondition fails returns False (the drivers just move on),
    and an op that would exhaust capacity grows the store first, which is
    precisely the elastic behaviour under fuzz."""

    def __init__(self):
        self.hg = H.from_lists(
            [], num_vertices=V0, max_edges=7, max_card=MAXC,
            max_vdeg=MAXVD, granule=GRANULE, slack=1.0, min_capacity=64)
        self.oracle: dict[int, set[int]] = {}
        self.vdeg: dict[int, int] = {}
        self.h2v_level_grows = 0
        self.v2h_level_grows = 0

    # ------------------------------------------------------------- helpers
    def live_ranks(self):
        return sorted(self.oracle)

    def _is_dup(self, vs: set) -> bool:
        return any(vs == s for s in self.oracle.values())

    def _ensure_h2v_space(self):
        """Grow before an insert that could overflow — capacity (worst case
        primary + replacement overflow) and rank space (no free node and no
        fresh rank left)."""
        h2v = self.hg.h2v
        worst = 4 * GRANULE
        if int(h2v.free_ptr) + worst > h2v.capacity:
            self.hg = E.grow_hypergraph(
                self.hg, h2v_capacity=2 * h2v.capacity)
        mgr = self.hg.h2v.mgr
        if (int(mgr.root_avail) == 0
                and int(self.hg.h2v.n_ranks) >= (1 << mgr.height) - 1):
            self.hg = E.grow_hypergraph(self.hg, h2v_levels=1)
            self.h2v_level_grows += 1

    def _ensure_v2h_space(self, n_members: int):
        v2h = self.hg.v2h
        worst = n_members * 2 * GRANULE
        if int(v2h.free_ptr) + worst > v2h.capacity:
            self.hg = E.grow_hypergraph(
                self.hg, v2h_capacity=2 * v2h.capacity)

    # --------------------------------------------------------------- rules
    def op_insert(self, vs: list[int]) -> bool:
        vs = sorted(set(v for v in vs if v < self.hg.num_vertices))
        if not 2 <= len(vs) <= MAXC or self._is_dup(set(vs)):
            return False
        if any(self.vdeg.get(v, 0) >= MAXVD for v in vs):
            return False
        self._ensure_h2v_space()
        self._ensure_v2h_space(len(vs))
        nl = np.full((1, MAXC), EMPTY, np.int32)
        nl[0, : len(vs)] = vs
        self.hg, ranks = _jit_insert(
            self.hg, jnp.asarray(nl), jnp.asarray([len(vs)], np.int32), _ONE)
        r = int(ranks[0])
        assert r >= 0 and r not in self.oracle
        self.oracle[r] = set(vs)
        for v in vs:
            self.vdeg[v] = self.vdeg.get(v, 0) + 1
        return True

    def op_delete(self, choice: int) -> bool:
        live = self.live_ranks()
        if not live:
            return False
        r = live[choice % len(live)]
        self.hg = _jit_delete(self.hg, jnp.asarray([r], np.int32), _ONE)
        for v in self.oracle.pop(r):
            self.vdeg[v] -= 1
        return True

    def op_vertex_update(self, choice: int, vid: int, insert: bool) -> bool:
        live = self.live_ranks()
        if not live:
            return False
        r = live[choice % len(live)]
        vid = vid % self.hg.num_vertices
        cur = self.oracle[r]
        if insert:
            if (vid in cur or len(cur) >= MAXC
                    or self.vdeg.get(vid, 0) >= MAXVD
                    or self._is_dup(cur | {vid})):
                return False
        else:
            if vid not in cur or len(cur) <= 2 or self._is_dup(cur - {vid}):
                return False
        self._ensure_v2h_space(1)
        self._ensure_h2v_space()
        self.hg = _jit_vupdate(
            self.hg, jnp.asarray([r], np.int32), jnp.asarray([vid], np.int32),
            jnp.asarray([insert]), _ONE)
        if insert:
            cur.add(vid)
            self.vdeg[vid] = self.vdeg.get(vid, 0) + 1
        else:
            cur.discard(vid)
            self.vdeg[vid] -= 1
        return True

    def op_grow(self, which: int) -> bool:
        hg = self.hg
        if which == 0:
            self.hg = E.grow_hypergraph(hg, h2v_capacity=2 * hg.h2v.capacity)
        elif which == 1:
            self.hg = E.grow_hypergraph(hg, v2h_capacity=2 * hg.v2h.capacity)
        elif which == 2:
            if self.h2v_level_grows >= MAX_LEVEL_GROWS:
                return False
            self.hg = E.grow_hypergraph(hg, h2v_levels=1)
            self.h2v_level_grows += 1
        else:
            if self.v2h_level_grows >= MAX_LEVEL_GROWS:
                return False
            self.hg = E.grow_hypergraph(
                hg, v2h_levels=1, v2h_capacity=2 * hg.v2h.capacity)
            self.v2h_level_grows += 1
        return True

    def op_compact(self) -> bool:
        self.hg = E.compact_hypergraph(self.hg)
        return True

    # -------------------------------------------------------------- checks
    def check_store(self):
        """The per-rule invariant: zero sticky errors and exact h2v + v2h
        agreement with the oracle (read_dense drives read_sorted, so row
        contents cover both)."""
        assert int(self.hg.h2v.error) == 0, "h2v sticky error"
        assert int(self.hg.v2h.error) == 0, "v2h sticky error"
        assert H.to_python(self.hg) == self.oracle
        # the dual mapping: vertex -> set of incident live ranks
        nv = self.hg.num_vertices
        rows = np.asarray(read_dense(self.hg.v2h, jnp.arange(nv)))
        want: dict[int, set[int]] = {}
        for r, vs in self.oracle.items():
            for v in vs:
                want.setdefault(v, set()).add(r)
        for v in range(nv):
            got = set(rows[v][rows[v] != EMPTY].tolist())
            assert got == want.get(v, set()), f"v2h[{v}]: {got}"

    def check_histogram(self):
        ref = BL.mochy_cpu([set(s) for s in self.oracle.values()])
        reg, m = T.all_live_region(self.hg, MAXR)
        got = T.count_triads(self.hg, reg, m, max_deg=MAXD, chunk=CHUNK)
        assert (np.asarray(got).astype(np.int64) == ref).all(), (
            f"histogram diverged: {np.asarray(got)} vs {ref}")


def _drive(model: ElasticModel, ops: list[tuple]):
    """Apply a decoded op list; shared by both drivers."""
    for op in ops:
        kind = op[0]
        if kind == "ins":
            model.op_insert(op[1])
        elif kind == "del":
            model.op_delete(op[1])
        elif kind == "vup":
            model.op_vertex_update(op[1], op[2], op[3])
        elif kind == "grow":
            model.op_grow(op[1])
        elif kind == "compact":
            model.op_compact()
        model.check_store()


def _random_ops(rng: np.random.Generator, n_steps: int) -> list[tuple]:
    ops: list[tuple] = []
    for _ in range(n_steps):
        roll = rng.random()
        if roll < 0.45:
            k = int(rng.integers(2, MAXC + 1))
            ops.append(("ins", rng.integers(0, 2 * V0, size=k).tolist()))
        elif roll < 0.6:
            ops.append(("del", int(rng.integers(0, 1 << 30))))
        elif roll < 0.8:
            ops.append(("vup", int(rng.integers(0, 1 << 30)),
                        int(rng.integers(0, 2 * V0)), bool(rng.random() < 0.6)))
        elif roll < 0.9:
            ops.append(("grow", int(rng.integers(0, 4))))
        else:
            ops.append(("compact",))
    return ops


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis present: the RuleBasedStateMachine "
                           "variant below runs instead")
def test_differential_fuzz_seeded():
    """Hypothesis-free differential fuzz: N_EXAMPLES seeded episodes, the
    same model/invariants as the state machine, zero divergences."""
    rng = np.random.default_rng(2024)
    for ep in range(N_EXAMPLES):
        model = ElasticModel()
        _drive(model, _random_ops(rng, STEPS))
        if ep % 4 == 0:
            model.check_histogram()


if HAVE_HYPOTHESIS:

    class ElasticStateMachine(RuleBasedStateMachine):
        """hypothesis stateful driver over the shared model.  Rules return
        early (not ``assume``) when a precondition fails, so every drawn
        step is cheap and shrinking stays effective."""

        def __init__(self):
            super().__init__()
            self.model = ElasticModel()

        @rule(vs=st.lists(st.integers(0, 2 * V0 - 1), min_size=2,
                          max_size=MAXC))
        def insert(self, vs):
            self.model.op_insert(vs)

        @rule(choice=st.integers(0, 1 << 30))
        def delete(self, choice):
            self.model.op_delete(choice)

        @rule(choice=st.integers(0, 1 << 30),
              vid=st.integers(0, 2 * V0 - 1), insert=st.booleans())
        def vertex_update(self, choice, vid, insert):
            self.model.op_vertex_update(choice, vid, insert)

        @rule(which=st.integers(0, 3))
        def grow(self, which):
            self.model.op_grow(which)

        @rule()
        def compact(self):
            self.model.op_compact()

        @rule()
        def histogram_checkpoint(self):
            self.model.check_histogram()

        @invariant()
        def store_matches_oracle(self):
            self.model.check_store()

    ElasticStateMachine.TestCase.settings = hypothesis.settings(
        max_examples=N_EXAMPLES,
        stateful_step_count=STEPS,
        deadline=None,
        suppress_health_check=list(hypothesis.HealthCheck),
        # no persisted example database (CI runners are ephemeral — a
        # saved counterexample would be lost anyway); print_blob gives a
        # @reproduce_failure decorator in the failure output instead
        database=None,
        print_blob=True,
    )

    TestElasticStateMachine = ElasticStateMachine.TestCase
