"""Temporal triads (THyMe+ window + ordered classes) vs brute force."""
from itertools import combinations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hypergraph as H
from repro.core import motifs, triads
from conftest import rand_hyperedges


def brute(edges, times, window):
    hist = np.zeros(motifs.NUM_TEMPORAL, np.int64)
    sets = [set(e) for e in edges]
    n = len(edges)
    for i, j, k in combinations(range(n), 3):
        a, b, c = sets[i], sets[j], sets[k]
        if (len(a & b) > 0) + (len(a & c) > 0) + (len(b & c) > 0) < 2:
            continue
        ts = [times[i], times[j], times[k]]
        if max(ts) - min(ts) > window:
            continue
        x, y, z = (sets[[i, j, k][o]] for o in np.argsort(ts, kind="stable"))
        code = int(motifs.region_code(
            np.int32(len(x)), np.int32(len(y)), np.int32(len(z)),
            np.int32(len(x & y)), np.int32(len(x & z)), np.int32(len(y & z)),
            np.int32(len(x & y & z))))
        hist[motifs.TEMPORAL_CLASS_ID[code]] += 1
    return hist


@pytest.mark.parametrize("seed,window", [(7, 300), (8, 100), (9, 1000)])
def test_temporal_matches_brute(seed, window):
    rng = np.random.default_rng(seed)
    edges = rand_hyperedges(rng, 20, 12)
    n = len(edges)
    times = rng.permutation(1000)[:n].astype(np.int32)  # distinct stamps
    hg = H.from_lists(edges, max_edges=64)
    tarr = np.zeros(hg.n_edge_slots, np.int32)
    tarr[:n] = times
    ranks = jnp.arange(64, dtype=jnp.int32)
    got = np.asarray(triads.count_triads(
        hg, ranks, ranks < n, max_deg=48, chunk=256,
        temporal=True, times=jnp.asarray(tarr), window=window))
    exp = brute(edges, times, window)
    assert (got == exp).all()


def test_window_zero_only_simultaneous():
    edges = [[0, 1], [1, 2], [0, 2]]
    hg = H.from_lists(edges, max_edges=16)
    tarr = np.zeros(hg.n_edge_slots, np.int32)
    tarr[:3] = [5, 5, 9]
    ranks = jnp.arange(16, dtype=jnp.int32)
    got = np.asarray(triads.count_triads(
        hg, ranks, ranks < 3, max_deg=8, chunk=64,
        temporal=True, times=jnp.asarray(tarr), window=0))
    assert int(got.sum()) == 0  # spread over 2 stamps > window 0
