"""Per-arch reduced-config smoke tests + sequence-model consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.models import api
from repro.models.rwkv6 import _wkv_chunked, _wkv_step
from repro.models.mamba import ssd_chunked, ssd_step
from repro.train import optimizer as OPT
from repro.train import train_step as TS

pytestmark = pytest.mark.slow


def _inputs(cfg, B, S, rng):
    kw = {}
    if cfg.family == "vlm":
        kw["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.vision_embed_dim)),
            jnp.float32)
    if cfg.family == "audio":
        kw["audio_feats"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.audio_feat_dim)), jnp.float32)
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(0)
    params, specs = api.init_params(cfg, jax.random.PRNGKey(0))
    assert set(specs) == set(params)
    B, S = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    kw = _inputs(cfg, B, S, rng)
    logits, _, aux = api.forward(cfg, params, tokens, **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    batch = dict(tokens=tokens,
                 labels=jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
                 **kw)
    state, _ = TS.init_train_state(cfg, jax.random.PRNGKey(1))
    step = TS.make_train_step(cfg, OPT.AdamWConfig(lr=1e-3, total_steps=10,
                                                   warmup_steps=1))
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["opt"]["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.abs(new_state["params"][k] - state["params"][k]).max()) > 0
        for k in state["params"])
    assert moved


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "hymba-1.5b", "rwkv6-1.6b",
                                  "moonshot-v1-16b-a3b"])
def test_prefill_decode_matches_teacher_forcing(arch):
    """Decode with cache must reproduce full-context logits."""
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(1)
    params, _ = api.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    full_logits, _, _ = api.forward(cfg, params, tokens)

    from repro.serve import serve_step as SRV
    prefill = SRV.make_prefill(cfg, max_seq=S + 4)
    decode = SRV.make_decode(cfg)
    cache = api.init_decode_state(cfg, B, S + 4, jnp.float32)
    split = S - 3
    last, cache = prefill(params, tokens[:, :split], cache)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, split - 1]),
                               atol=2e-3, rtol=2e-3)
    for t in range(split, S):
        last, cache = decode(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(last),
                                   np.asarray(full_logits[:, t]),
                                   atol=2e-3, rtol=2e-3)


def test_wkv_chunked_equals_naive():
    rng = np.random.default_rng(0)
    B, T, H, hd = 2, 37, 3, 8
    r, k, v = (jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
               for _ in range(3))
    logw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((B, H, hd, hd)), jnp.float32)
    s = s0
    ys = []
    for t in range(T):
        y, s = _wkv_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, s)
        ys.append(np.asarray(y))
    y_c, s_c = _wkv_chunked(r, k, v, logw, u, s0, chunk=8)
    np.testing.assert_allclose(np.asarray(y_c), np.stack(ys, 1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s), atol=1e-4)


def test_ssd_chunked_equals_naive():
    rng = np.random.default_rng(1)
    Bt, T, H, dh, n = 2, 29, 3, 8, 4
    x = jnp.asarray(rng.standard_normal((Bt, T, H, dh)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (Bt, T, H)), jnp.float32)
    loga = -jnp.asarray(rng.uniform(0.01, 1.0, (Bt, T, H)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((Bt, T, n)), jnp.float32)  # head-shared
    Cm = jnp.asarray(rng.standard_normal((Bt, T, n)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((Bt, H, n, dh)), jnp.float32)
    h = h0
    ys = []
    for t in range(T):
        y, h = ssd_step(x[:, t], dt[:, t], Bm[:, t], Cm[:, t], loga[:, t], h)
        ys.append(np.asarray(y))
    y_c, h_c = ssd_chunked(x, dt, Bm, Cm, loga, h0, chunk=8)
    np.testing.assert_allclose(np.asarray(y_c), np.stack(ys, 1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h), atol=1e-4)


def test_moe_matches_dense_per_expert_reference():
    """Sort-based dispatch == explicit per-token top-k loop (numpy oracle)."""
    from repro.models import moe as MOE
    from repro.models.layers import ParamBuilder
    cfg = get_arch("phi3.5-moe-42b-a6.6b").reduced()
    rng = np.random.default_rng(3)
    b = ParamBuilder(jax.random.PRNGKey(3))
    MOE.moe_params(b, cfg, "", 1)
    lp = {k: v[0] for k, v in b.params.items()}
    x = jnp.asarray(rng.standard_normal((2, 6, cfg.d_model)), jnp.float32)
    y, aux = MOE.moe_apply(lp, cfg, x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    assert float(aux) > 0.5  # balanced-ish routing at init (≈1)

    # numpy oracle: per-token dense top-k expert mix (capacity unbounded here;
    # capacity >= tokens*k/E*cf is large enough at this size to drop nothing)
    xf = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xf @ np.asarray(lp["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    exp_out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[: cfg.top_k]
        w = probs[t][top] / probs[t][top].sum()
        for e, wi in zip(top, w):
            g = xf[t] @ np.asarray(lp["w_gate"])[e]
            u = xf[t] @ np.asarray(lp["w_up"])[e]
            h = (g / (1 + np.exp(-g))) * u
            exp_out[t] += wi * (h @ np.asarray(lp["w_down"])[e])
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model),
                               exp_out, atol=2e-4, rtol=2e-4)
