import os
import sys

# tests run on the single real CPU device — the 512-device override is
# exclusive to launch/dryrun.py (see assignment step 0)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: full system/model sweeps (minutes); deselect with "
        "-m 'not slow' for the fast tier (see README.md)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def rand_hyperedges(rng, n, n_vertices, lo=2, hi=5):
    out, seen = [], set()
    tries = 0
    while len(out) < n and tries < 50 * n:
        tries += 1
        k = int(rng.integers(lo, min(hi, n_vertices)))
        e = tuple(sorted(rng.choice(n_vertices, size=k, replace=False).tolist()))
        if e not in seen:
            seen.add(e)
            out.append(list(e))
    return out
