"""Triad query service (src/repro/query/, DESIGN.md §7).

The coherence contract under test: every answer served through
snapshot + batching + cache during an active stream is bit-identical to a
fresh recount of the same quantity at the same epoch — single-device and
sharded.  Plus the subsystem-level oracles: brute-force top-k (order
included: ties must break deterministically toward the smallest triple)
and batched-vs-sequential point-query parity across all three kernel
backends.

Graphs are tiny on purpose (the pallas backend runs in interpret mode on
CPU); on a 1-device host the sharded parity degenerates to a 1-way mesh —
the CI distributed job re-runs this file on a real 8-way host mesh.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import motifs
from repro.core import stream as S
from repro.core import triads as T
from repro.core import vertex_triads as VT
from repro.distributed import triads as DT
from repro.hypergraph import generators as GEN
from repro import query

BACKENDS = ("xla", "pallas", "bitset")
# max_deg=32 exceeds the largest possible line-graph degree at these sizes:
# the brute-force oracles see untruncated neighbourhoods, so the engine
# must too for the comparisons to be exact
V, MAXC, MAXD, MAXNB, MAXR, CHUNK = 16, 8, 32, 16, 63, 64
KW = dict(max_deg=MAXD, max_nb=MAXNB, max_region=MAXR, chunk=CHUNK)


def _hg(n_edges=24, seed=0):
    edges = GEN.random_hypergraph(n_edges, V, profile="coauth", max_card=6,
                                  seed=seed, skew=0.3)
    return H.from_lists(edges, num_vertices=V, max_edges=4 * n_edges,
                        max_card=MAXC, slack=4.0)


def _brute_topk(hg, k, score=None):
    """All connected hyperedge triples, scored from python sets, sorted by
    (-score, a, b, c) — the oracle for topk_triplets including tie order."""
    py = H.to_python(hg)
    out = []
    for a, b, c in itertools.combinations(sorted(py), 3):
        A, B, C = py[a], py[b], py[c]
        iab, iac, ibc = len(A & B), len(A & C), len(B & C)
        if (iab > 0) + (iac > 0) + (ibc > 0) < 2:
            continue                      # not connected
        iabc = len(A & B & C)
        s = iabc if score is None else score(iab, iac, ibc, iabc,
                                             len(A), len(B), len(C))
        out.append((s, (a, b, c)))
    out.sort(key=lambda x: (-x[0], x[1]))
    return out[:k]


def _topk_host(res):
    return [(int(s), tuple(map(int, t)))
            for s, t in zip(res.scores, res.triples) if s >= 0]


# ---------------------------------------------------------------- top-k

def test_topk_matches_bruteforce_with_ties():
    hg = _hg(30, seed=2)
    reg, m = T.all_live_region(hg, MAXR)
    res = query.run_topk(hg, reg, m, k=12, max_deg=MAXD, chunk=CHUNK)
    want = _brute_topk(hg, 12)
    assert _topk_host(res) == [(s, t) for s, t in want]
    # the oracle list contains ties (that is what makes the order check
    # meaningful) — guard the fixture against drifting into all-distinct
    scores = [s for s, _ in want]
    assert len(set(scores)) < len(scores)


def test_topk_k_exceeds_triples_and_pluggable_score():
    hg = _hg(8, seed=3)
    reg, m = T.all_live_region(hg, MAXR)
    big = 64
    res = query.run_topk(hg, reg, m, k=big, max_deg=MAXD, chunk=CHUNK)
    want = _brute_topk(hg, big)
    got = _topk_host(res)
    assert got == want                    # fewer than k: rest invalid
    assert int(np.asarray(res.valid).sum()) == len(want)

    def score(iab, iac, ibc, iabc, ca, cb, cc):
        return iab + iac + ibc + 5 * iabc

    res = query.run_topk(hg, reg, m, k=8, max_deg=MAXD, chunk=CHUNK,
                         score=score)
    want = _brute_topk(hg, 8, score=lambda iab, iac, ibc, iabc, ca, cb, cc:
                       iab + iac + ibc + 5 * iabc)
    assert _topk_host(res) == want


# --------------------------------------------- batched point queries

@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_point_edge_matches_sequential(backend):
    """count_triads_containing_each row q == count_triads_containing of the
    single edge q — the batched form is a launch-count optimisation, not a
    semantic change — on every kernel backend."""
    hg = _hg()
    live = H.live_ranks_host(hg)
    q = jnp.asarray(live[:6].astype(np.int32))
    m = jnp.ones(6, bool)
    batched = T.count_triads_containing_each(
        hg, q, m, max_deg=MAXD, chunk=CHUNK, backend=backend)
    for i in range(6):
        single = T.count_triads_containing(
            hg, q[i: i + 1], m[:1], max_deg=MAXD, chunk=CHUNK,
            backend=backend)
        assert (np.asarray(batched[i]) == np.asarray(single)).all(), i


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_point_vertex_matches_region_recount(backend):
    """count_vertex_triads_at row q == count_vertex_triads over the closed
    neighbourhood N[vids[q]], on every kernel backend."""
    hg = _hg()
    vids = jnp.arange(8, dtype=jnp.int32)
    m = jnp.ones(8, bool)
    batched = VT.count_vertex_triads_at(
        hg, vids, m, V, max_nb=MAXNB, chunk=CHUNK, backend=backend)
    reg, rm = VT.point_region(hg, vids, m, max_nb=MAXNB)
    for i in range(8):
        single = VT.count_vertex_triads(
            hg, reg[i], rm[i], V, max_nb=MAXNB, chunk=CHUNK, backend=backend)
        assert (np.asarray(batched[i]) == np.asarray(single)).all(), i


def test_batched_point_edge_temporal_parity():
    """Temporal classification (δ-window) threads through the batched form
    identically to the single-edge core."""
    hg = _hg()
    times = jnp.arange(hg.n_edge_slots, dtype=jnp.int32) * 7 + 1
    live = H.live_ranks_host(hg)
    q = jnp.asarray(live[:4].astype(np.int32))
    m = jnp.ones(4, bool)
    kw = dict(max_deg=MAXD, chunk=CHUNK, temporal=True, times=times,
              window=40)
    batched = T.count_triads_containing_each(hg, q, m, **kw)
    for i in range(4):
        single = T.count_triads_containing(hg, q[i: i + 1], m[:1], **kw)
        assert (np.asarray(batched[i]) == np.asarray(single)).all(), i


def test_batched_point_edge_with_neighbor_index():
    """The epoch-level neighbour table is a pure gather cache: answers with
    nbrs_table are bit-identical to the table-less path (and the table rows
    equal per-call ``neighbors``)."""
    from repro.core.hypergraph import neighbors

    hg = _hg()
    live = H.live_ranks_host(hg)
    q = jnp.asarray(live[:6].astype(np.int32))
    m = jnp.ones(6, bool)
    table = T.neighbor_table(hg, max_deg=MAXD, block=32)
    rows = neighbors(hg, jnp.asarray(live.astype(np.int32)), MAXD)
    got = table[jnp.asarray(live.astype(np.int32))]
    assert (np.asarray(got) == np.asarray(rows)).all()
    plain = T.count_triads_containing_each(hg, q, m, max_deg=MAXD,
                                           chunk=CHUNK)
    indexed = T.count_triads_containing_each(hg, q, m, max_deg=MAXD,
                                             chunk=CHUNK, nbrs_table=table)
    assert (np.asarray(plain) == np.asarray(indexed)).all()


def test_batched_point_edge_dead_and_duplicate_ranks():
    hg = _hg()
    live = H.live_ranks_host(hg)
    dead = next(r for r in range(hg.n_edge_slots) if r not in set(live))
    q = jnp.asarray([live[0], dead, live[0], live[1]], dtype=jnp.int32)
    m = jnp.asarray([True, True, True, False])
    out = np.asarray(T.count_triads_containing_each(
        hg, q, m, max_deg=MAXD, chunk=CHUNK))
    assert (out[0] == out[2]).all() and out[0].sum() > 0
    assert out[1].sum() == 0 and out[3].sum() == 0


# ------------------------------------------------- stream + snapshot

def _empty_hg():
    return H.from_lists([], num_vertices=V, max_edges=128, max_card=MAXC,
                        max_vdeg=64, min_capacity=4096)


def _check_coherent(snap, cache, mesh=None):
    """Serve a full query battery against ``snap`` and compare every answer
    with a fresh recount of the same quantity on the snapshot's graph."""
    hg = snap.hg
    live = H.live_ranks_host(hg)
    reqs = ([query.triads_containing_edge(int(r)) for r in live[:5]]
            + [query.triads_at_vertex(v) for v in range(4)]
            + [query.topk_triplets(6), query.histogram()])
    serve_kw = dict(v_total=V, cache=cache, **KW)
    if mesh is not None:
        out = DT.serve_queries(snap, reqs, mesh=mesh, **serve_kw)
    else:
        out = query.serve(snap, reqs, **serve_kw)

    n_e = len(live[:5])
    for j, r in enumerate(live[:5]):
        ref = T.count_triads_containing(
            hg, jnp.asarray([int(r)], jnp.int32), jnp.ones(1, bool),
            max_deg=MAXD, chunk=CHUNK)
        assert (out[j] == np.asarray(ref)).all(), f"edge {r} at epoch {snap.epoch}"
    reg, rm = VT.point_region(hg, jnp.arange(4, dtype=jnp.int32),
                              jnp.ones(4, bool), max_nb=MAXNB)
    for v in range(4):
        ref = VT.count_vertex_triads(hg, reg[v], rm[v], V, max_nb=MAXNB,
                                     chunk=CHUNK)
        assert (out[n_e + v] == np.asarray(ref)).all(), f"vertex {v}"
    want = _brute_topk(hg, 6)
    assert _topk_host(out[n_e + 4]) == want
    areg, am = T.all_live_region(hg, MAXR)
    ref = T.count_triads(hg, areg, am, max_deg=MAXD, chunk=CHUNK)
    assert (out[n_e + 5] == np.asarray(ref)).all()
    return out


def test_interleaved_stream_and_queries_coherent():
    """The acceptance contract: queries served from snapshot + cache while
    the stream keeps mutating match a fresh recount at the same epoch; the
    warm cache actually gets hits and stays exact."""
    events = GEN.event_stream(40, V, seed=1, max_card=6, insert_frac=0.7)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(_empty_hg(), log, jnp.zeros(motifs.NUM_CLASSES,
                                                   jnp.int32))
    n_steps = S.plan_steps(events, 8)
    cache = query.QueryCache()
    run_kw = dict(batch=8, mode="edge", max_deg=MAXD, max_nb=MAXNB,
                  max_region=MAXR, chunk=CHUNK)
    done = 0
    while done < n_steps:
        step = min(3, n_steps - done)
        st = S.run_stream(st, n_steps=step, **run_kw)
        done += step
        assert int(st.error) == 0
        snap = query.of_stream(st)
        assert snap.epoch == done
        _check_coherent(snap, cache)
        # repeat traffic at the same epoch: answers must come warm (this
        # tiny dense graph dirties ~everything every batch, so cross-epoch
        # hits are exercised separately in test_dirty_epoch_maps_localised)
        h0 = cache.hits
        _check_coherent(snap, cache)
        assert cache.hits > h0
    assert int(st.log.n_pending) == 0


def test_snapshot_isolation_under_further_churn():
    """A snapshot keeps answering at ITS epoch after the stream moves on —
    jax immutability makes the old arrays a free double buffer."""
    events = GEN.event_stream(30, V, seed=4, max_card=6, insert_frac=0.8)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(_empty_hg(), log, jnp.zeros(motifs.NUM_CLASSES,
                                                   jnp.int32))
    n_steps = S.plan_steps(events, 8)
    st = S.run_stream(st, n_steps=2, batch=8, mode="edge", max_deg=MAXD,
                      max_region=MAXR, chunk=CHUNK)
    snap_old = query.of_stream(st)
    before = _check_coherent(snap_old, cache=None)
    st = S.run_stream(st, n_steps=n_steps - 2, batch=8, mode="edge",
                      max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    assert int(st.epoch) == n_steps
    after = _check_coherent(snap_old, cache=None)    # old snapshot, again
    for x, y in zip(before, after):
        if isinstance(x, query.TopK):
            assert (x.scores == y.scores).all()
        else:
            assert (x == y).all()
    _check_coherent(query.of_stream(st), cache=None)  # and the new epoch


def test_dirty_epoch_maps_localised():
    """Two line-graph components; churn inside one.  The other component's
    edges/vertices stay clean (dirty_epoch unchanged), so their cached
    answers survive — and the last batch's touched slots are recoverable as
    dirty_epoch == epoch (observability)."""
    # component A on vertices 0..5, component B on 8..13 (disjoint)
    ev = [(0, "ins", [0, 1, 2]), (1, "ins", [1, 2, 3]), (2, "ins", [2, 3, 4]),
          (3, "ins", [8, 9, 10]), (4, "ins", [9, 10, 11]),
          (5, "ins", [10, 11, 12])]
    log = S.log_from_events(ev, max_card=MAXC, capacity=16)
    st = S.make_stream(_empty_hg(), log, jnp.zeros(motifs.NUM_CLASSES,
                                                   jnp.int32))
    run_kw = dict(batch=8, mode="edge", max_deg=MAXD, max_nb=MAXNB,
                  max_region=MAXR, chunk=CHUNK)
    st = S.run_stream(st, n_steps=1, **run_kw)
    cache = query.QueryCache()
    snap1 = query.of_stream(st)
    out1 = _check_coherent(snap1, cache)
    h1, m1 = cache.hits, cache.misses

    # churn component A only: delete its first edge
    st = dataclasses.replace(st, log=S.push_events(
        st.log, jnp.asarray([10]), jnp.asarray([S.DEL]),
        jnp.full((1, MAXC), jnp.iinfo(jnp.int32).max, jnp.int32),
        jnp.asarray([0]), jnp.asarray([0]), jnp.ones(1, bool)))
    st = S.run_stream(st, n_steps=1, **run_kw)
    assert int(st.error) == 0
    snap2 = query.of_stream(st)

    # component B untouched: its ranks keep dirty_epoch from insertion time
    rank_a = int(np.asarray(st.rank_of)[1])   # a surviving A edge
    rank_b = int(np.asarray(st.rank_of)[3])   # a B edge
    assert snap2.edge_dirty(rank_a) == snap2.epoch      # A dirtied now
    assert snap2.edge_dirty(rank_b) < snap2.epoch       # B still clean
    assert snap2.vertex_dirty(0) == snap2.epoch
    assert snap2.vertex_dirty(12) < snap2.epoch
    # the last batch's touched edge set is exactly dirty_epoch == epoch
    last = np.nonzero(np.asarray(st.dirty_epoch) == int(st.epoch))[0]
    assert rank_a in last and rank_b not in last

    out2 = _check_coherent(snap2, cache)
    # B's point answers were served from cache (hits grew), yet exact
    assert cache.hits > h1
    del out1, out2, m1


def test_cache_keys_include_serve_params():
    """The same rank served under different parameters (bounds, temporal
    family) must not cross-serve cached answers — regression for the
    params-blind cache key."""
    events = GEN.event_stream(30, V, seed=8, max_card=6, insert_frac=0.8)
    st = S.make_stream(_empty_hg(), S.log_from_events(events, max_card=MAXC),
                       jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    st = S.run_stream(st, n_steps=S.plan_steps(events, 8), batch=8,
                      mode="edge", max_deg=MAXD, max_region=MAXR,
                      chunk=CHUNK)
    snap = query.of_stream(st)
    r = int(H.live_ranks_host(snap.hg)[0])
    cache = query.QueryCache()
    req = [query.triads_containing_edge(r)]
    full = query.serve(snap, req, cache=cache, **KW)[0]
    # tighter degree bound: different (smaller) answer, not the cached one
    kw8 = dict(KW, max_deg=8)
    trunc = query.serve(snap, req, cache=cache, **kw8)[0]
    ref8 = T.count_triads_containing(
        snap.hg, jnp.asarray([r], jnp.int32), jnp.ones(1, bool),
        max_deg=8, chunk=CHUNK)
    assert (trunc == np.asarray(ref8)).all()
    # temporal family: different shape entirely
    temp = query.serve(snap, req, cache=cache, temporal=True, window=40,
                       **KW)[0]
    assert temp.shape == (motifs.NUM_TEMPORAL,)
    assert full.shape == (motifs.NUM_CLASSES,)
    # and the original parameters still serve the original answer, warm
    again = query.serve(snap, req, cache=cache, **KW)[0]
    assert (again == full).all()


def test_out_of_range_keys_answer_zeros():
    """Ranks/vids outside the store's address space answer all-zeros and
    never touch the device or crash the cache's dirty-map lookup."""
    events = GEN.event_stream(20, V, seed=9, max_card=6, insert_frac=0.9)
    st = S.make_stream(_empty_hg(), S.log_from_events(events, max_card=MAXC),
                       jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    st = S.run_stream(st, n_steps=S.plan_steps(events, 8), batch=8,
                      mode="edge", max_deg=MAXD, max_region=MAXR,
                      chunk=CHUNK)
    snap = query.of_stream(st)
    cache = query.QueryCache()
    reqs = [query.triads_containing_edge(snap.hg.n_edge_slots + 3),
            query.triads_containing_edge(-1),
            query.triads_at_vertex(snap.hg.num_vertices + 7),
            query.triads_at_vertex(-2)]
    out = query.serve(snap, reqs, cache=cache, v_total=V, **KW)
    assert out[0].sum() == 0 and out[1].sum() == 0
    assert out[2].sum() == 0 and out[3].sum() == 0

    # served arrays are frozen: a consumer mutating an answer errors
    # instead of corrupting the shared cache entry
    live = H.live_ranks_host(snap.hg)
    ans = query.serve(snap, [query.triads_containing_edge(int(live[0]))],
                      cache=cache, **KW)[0]
    with pytest.raises(ValueError):
        ans[0] = 99

    # a top-k/histogram region that cannot hold every live edge is refused,
    # not silently truncated
    with pytest.raises(ValueError, match="live hyperedges"):
        query.serve(snap, [query.topk_triplets(3)],
                    **dict(KW, max_region=3))


def test_track_dirty_false_is_conservative_and_exact():
    """track_dirty=False skips the derived-family closure: the vertex map
    bumps wholesale (nothing vertex-cached survives an epoch), the edge
    map stays exact from the counting by-product, and answers are still
    coherent."""
    events = GEN.event_stream(30, V, seed=10, max_card=6, insert_frac=0.8)
    st = S.make_stream(_empty_hg(), S.log_from_events(events, max_card=MAXC),
                       jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    st = S.run_stream(st, n_steps=S.plan_steps(events, 8), batch=8,
                      mode="edge", max_deg=MAXD, max_region=MAXR,
                      chunk=CHUNK, track_dirty=False)
    assert int(st.error) == 0
    # vertex map: every entry carries some epoch > 0 (always-dirty)
    assert int(np.asarray(st.v_dirty_epoch).min()) > 0
    _check_coherent(query.of_stream(st), cache=query.QueryCache())


def test_serve_sharded_parity():
    """serve_queries(mesh=...) == serve() bit-identically, mid-stream, for
    a mixed batch — on however many host devices this run has."""
    mesh = DT.count_mesh(min(8, len(jax.devices())))
    events = GEN.event_stream(30, V, seed=6, max_card=6, insert_frac=0.75)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(_empty_hg(), log, jnp.zeros(motifs.NUM_CLASSES,
                                                   jnp.int32))
    st = S.run_stream(st, n_steps=2, batch=8, mode="edge", max_deg=MAXD,
                      max_region=MAXR, chunk=CHUNK)
    snap = query.of_stream(st)
    _check_coherent(snap, cache=None, mesh=mesh)


def test_vertex_mode_stream_dirty_and_queries():
    """Vertex-mode streams maintain both dirty maps too; vertex point
    queries + histogram stay coherent at every snapshot."""
    events = GEN.event_stream(24, V, seed=7, max_card=6, insert_frac=0.8)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(_empty_hg(), log, jnp.zeros(3, jnp.int32))
    n_steps = S.plan_steps(events, 8)
    st = S.run_stream(st, n_steps=n_steps, batch=8, mode="vertex",
                      max_nb=MAXNB, max_deg=MAXD, max_region=MAXR,
                      chunk=CHUNK, v_total=V)
    assert int(st.error) == 0
    snap = query.of_stream(st)
    out = query.serve(snap, [query.triads_at_vertex(2), query.histogram()],
                      v_total=V, **KW)
    reg, rm = VT.point_region(snap.hg, jnp.asarray([2], jnp.int32),
                              jnp.ones(1, bool), max_nb=MAXNB)
    ref = VT.count_vertex_triads(snap.hg, reg[0], rm[0], V, max_nb=MAXNB,
                                 chunk=CHUNK)
    assert (out[0] == np.asarray(ref)).all()
    ref = BL.stathyper_static(snap.hg, V, max_nb=MAXNB, max_region=V,
                              chunk=CHUNK)
    assert (out[1] == np.asarray(ref)).all()
    assert int(np.asarray(st.dirty_epoch).max()) > 0     # edge map tracked


def test_interleaved_ingest_query_hypothesis():
    """Property form of the coherence contract: random interleavings of
    ingest and point queries always match a fresh recount at the same
    epoch, warm or cold cache."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as hst

    @settings(max_examples=5, deadline=None)
    @given(seed=hst.integers(0, 50), cut=hst.integers(1, 5))
    def prop(seed, cut):
        events = GEN.event_stream(24, V, seed=seed, max_card=6,
                                  insert_frac=0.7)
        log = S.log_from_events(events, max_card=MAXC)
        st = S.make_stream(_empty_hg(), log,
                           jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
        n_steps = S.plan_steps(events, 8)
        cache = query.QueryCache()
        run_kw = dict(batch=8, mode="edge", max_deg=MAXD, max_nb=MAXNB,
                      max_region=MAXR, chunk=CHUNK)
        done = 0
        while done < n_steps:
            step = min(cut, n_steps - done)
            st = S.run_stream(st, n_steps=step, **run_kw)
            done += step
            snap = query.of_stream(st)
            live = H.live_ranks_host(snap.hg)
            reqs = [query.triads_containing_edge(int(r)) for r in live[:4]]
            out = query.serve(snap, reqs, cache=cache, v_total=V, **KW)
            for j, r in enumerate(live[:4]):
                ref = T.count_triads_containing(
                    snap.hg, jnp.asarray([int(r)], jnp.int32),
                    jnp.ones(1, bool), max_deg=MAXD, chunk=CHUNK)
                assert (out[j] == np.asarray(ref)).all(), (seed, done, r)

    prop()
