"""Per-kernel shape/dtype sweeps: Pallas interpret vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import intersect as K
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention

EMPTY = np.iinfo(np.int32).max


def mksets(rng, n, c, univ):
    out = np.full((n, c), EMPTY, np.int32)
    for i in range(n):
        k = int(rng.integers(0, c + 1))
        if k:
            out[i, :k] = np.sort(rng.choice(univ, size=k, replace=False))
    return jnp.asarray(out)


@pytest.mark.parametrize("n,c", [(1, 8), (7, 16), (33, 32), (5, 128), (64, 64)])
def test_pair_intersect_sweep(n, c):
    rng = np.random.default_rng(n * 100 + c)
    x, y = mksets(rng, n, c, 3 * c), mksets(rng, n, c, 3 * c)
    got = K.pair_intersect_count(x, y)
    exp = R.pair_intersect_count(x, y)
    assert (np.asarray(got) == np.asarray(exp)).all()


@pytest.mark.parametrize("n,c", [(4, 8), (17, 32), (3, 128)])
def test_membership_sweep(n, c):
    rng = np.random.default_rng(n + c)
    x, y = mksets(rng, n, c, 2 * c), mksets(rng, n, c, 2 * c)
    assert (np.asarray(K.membership(x, y)) == np.asarray(R.membership(x, y))).all()


@pytest.mark.parametrize("n,k,c", [(3, 2, 8), (9, 5, 16), (2, 11, 64)])
def test_triple_intersect_sweep(n, k, c):
    rng = np.random.default_rng(n * k + c)
    a, b = mksets(rng, n, c, 2 * c), mksets(rng, n, c, 2 * c)
    cand = jnp.stack([mksets(rng, k, c, 2 * c) for _ in range(n)])
    got = K.triple_intersect_count(a, b, cand)
    exp = R.triple_intersect_count(a, b, cand)
    assert (np.asarray(got) == np.asarray(exp)).all()


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,window",
    [
        (2, 4, 2, 64, 64, 16, None),     # GQA causal
        (1, 2, 2, 48, 48, 32, None),     # ragged blocks
        (2, 4, 2, 1, 64, 16, None),      # decode
        (1, 4, 1, 64, 64, 16, 16),       # sliding window + MQA
    ],
)
def test_flash_attention_sweep(b, hq, hkv, sq, skv, d, window, dtype, atol):
    rng = np.random.default_rng(abs(hash((b, hq, sq, skv, d, str(window)))) % 2**31)
    t = lambda *s: jnp.asarray(rng.standard_normal(s), dtype)
    q, k, v = t(b, hq, sq, d), t(b, hkv, skv, d), t(b, hkv, skv, d)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32)
    rep = hq // hkv
    exp = R.flash_attention(q, jnp.repeat(k, rep, 1), jnp.repeat(v, rep, 1),
                            causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=atol)


def test_blockwise_xla_matches_dense():
    import repro.models.layers as lyr
    rng = np.random.default_rng(5)
    B, K_, G, S, hd = 1, 2, 2, 100, 16
    qg = jnp.asarray(rng.standard_normal((B, K_, G, S, hd)), jnp.float32)
    kt = jnp.asarray(rng.standard_normal((B, K_, S, hd)), jnp.float32)
    vt = jnp.asarray(rng.standard_normal((B, K_, S, hd)), jnp.float32)
    qa = jnp.arange(S)
    old = lyr._BLK_Q, lyr._BLK_KV
    lyr._BLK_Q, lyr._BLK_KV = 32, 16
    try:
        got = lyr._blockwise_attention(qg, kt, vt, qa, masked=True, window=None)
    finally:
        lyr._BLK_Q, lyr._BLK_KV = old
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg, kt) * hd ** -0.5
    mask = jnp.arange(S)[None, :] <= qa[:, None]
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    exp = jnp.einsum("bkgqs,bksd->bkgqd", jax.nn.softmax(logits, -1), vt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-5)
