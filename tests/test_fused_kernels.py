"""Fused one-pass multi-intersection kernel + packed-bitset backend vs the
kernels/ref.py oracles, on adversarial inputs: all-EMPTY rows, duplicate
values within rows, widths that are not a multiple of 128, and k=1 stacks.

Two layers:
  * parametrized sweeps that always run (no optional deps);
  * hypothesis property tests (skipped when hypothesis is absent, like
    test_hypergraph_property.py) that fuzz shapes/values/duplication.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bitset as BS
from repro.kernels import intersect as K
from repro.kernels import ops as kops
from repro.kernels import ref as R

EMPTY = np.iinfo(np.int32).max

BACKENDS = ("pallas", "xla", "bitset")


def mksets(rng, n, c, univ, dup_frac=0.0):
    """EMPTY-padded rows over [0, univ); a dup_frac share of rows contain
    repeated values (the adversarial case the first-occurrence masks cover)."""
    out = np.full((n, c), EMPTY, np.int32)
    for i in range(n):
        dups = rng.random() < dup_frac
        hi = c + 1 if dups else min(c, univ) + 1
        k = int(rng.integers(0, hi))
        if k:
            out[i, :k] = np.sort(rng.choice(univ, size=k, replace=dups))
    return jnp.asarray(out)


def assert_fused_matches(a, b, cand, n_bits):
    exp = R.fused_triple_stats(a, b, cand)
    for backend in BACKENDS:
        got = kops.fused_triple_stats(a, b, cand, backend=backend,
                                      n_bits=n_bits)
        for name, g, e in zip(("iab", "iac", "ibc", "iabc"), got, exp):
            assert (np.asarray(g) == np.asarray(e)).all(), (backend, name)


@pytest.mark.parametrize("n,k,c,univ", [
    (1, 1, 8, 40),        # k=1
    (7, 3, 16, 33),       # universe not a multiple of 32
    (5, 4, 100, 64),      # c not a multiple of 128 (or of anything)
    (9, 2, 130, 300),     # c > 128, still not lane-aligned
    (3, 5, 8, 1),         # single-value universe: maximal overlap
])
@pytest.mark.parametrize("dup_frac", [0.0, 0.7])
def test_fused_triple_stats_sweep(n, k, c, univ, dup_frac):
    rng = np.random.default_rng(n * 1000 + k * 100 + c + int(dup_frac * 10))
    a = mksets(rng, n, c, univ, dup_frac)
    b = mksets(rng, n, c, univ, dup_frac)
    cand = jnp.stack([mksets(rng, k, c, univ, dup_frac) for _ in range(n)])
    assert_fused_matches(a, b, cand, univ)


def test_all_empty_rows():
    a = jnp.full((4, 16), EMPTY, jnp.int32)
    cand = jnp.full((4, 2, 16), EMPTY, jnp.int32)
    for backend in BACKENDS:
        got = kops.fused_triple_stats(a, a, cand, backend=backend, n_bits=50)
        assert all(int(np.asarray(g).sum()) == 0 for g in got)


def test_fused_equals_unfused_on_duplicate_free_rows():
    """On set-semantic rows (what every counting consumer feeds) the fused
    stats equal the historical unfused oracle sequence exactly — this is the
    invariant that makes the rewiring histogram-preserving."""
    rng = np.random.default_rng(7)
    a, b = mksets(rng, 11, 24, 60), mksets(rng, 11, 24, 60)
    cand = jnp.stack([mksets(rng, 5, 24, 60) for _ in range(11)])
    iab, iac, ibc, iabc = R.fused_triple_stats(a, b, cand)
    assert (np.asarray(iab) == np.asarray(R.pair_intersect_count(a, b))).all()
    assert (np.asarray(iac) ==
            np.asarray(R.stack_pair_intersect_count(a, cand))).all()
    assert (np.asarray(ibc) ==
            np.asarray(R.stack_pair_intersect_count(b, cand))).all()
    assert (np.asarray(iabc) ==
            np.asarray(R.triple_intersect_count(a, b, cand))).all()


def test_pallas_fused_respects_small_blocks():
    """Force multi-program grids (block_rows=2, block_k=2) so the BlockSpec
    index maps and the redundant iab writes are actually exercised."""
    rng = np.random.default_rng(3)
    a, b = mksets(rng, 9, 16, 30), mksets(rng, 9, 16, 30)
    cand = jnp.stack([mksets(rng, 5, 16, 30) for _ in range(9)])
    got = K.fused_triple_stats(a, b, cand, block_rows=2, block_k=2)
    exp = R.fused_triple_stats(a, b, cand)
    for g, e in zip(got, exp):
        assert (np.asarray(g) == np.asarray(e)).all()


# ------------------------------------------------------------------ bitset
@pytest.mark.parametrize("n_bits", [1, 31, 32, 33, 100, 1000])
def test_pack_bitset_roundtrip(n_bits):
    rng = np.random.default_rng(n_bits)
    x = mksets(rng, 6, 12, n_bits, dup_frac=0.5)
    packed = BS.pack_bitset(x, n_bits)
    assert packed.shape == (6, BS.bitset_words(n_bits))
    xs = np.asarray(x)
    for i in range(6):
        want = {v for v in xs[i] if v != EMPTY}
        got = {w * 32 + t for w in range(packed.shape[1])
               for t in range(32) if (int(packed[i, w]) >> t) & 1}
        assert got == want


def test_pack_bitset_drops_out_of_universe():
    # values >= n_bits cannot be represented; they must vanish, not alias
    x = jnp.asarray([[0, 31, 32, 33, EMPTY]], jnp.int32)
    packed = BS.pack_bitset(x, 33)     # W=2; 33 would alias bit 1 of word 1
    assert int(packed[0, 0]) == (1 << 0) | (1 << 31)
    assert int(packed[0, 1]) == 1      # bit 32 (the last in-universe value)


def test_pack_bitset_assume_sorted_fast_path():
    """assume_sorted=True must agree with the general path on sorted rows
    (what read_sorted / dedupe_sorted feed the counting consumers) —
    including sorted rows with adjacent duplicates, since nothing in the
    insert path enforces duplicate-free user edges."""
    rng = np.random.default_rng(21)
    x = mksets(rng, 7, 12, 50)                  # sorted, duplicate-free
    general = BS.pack_bitset(x, 50)
    fast = BS.pack_bitset(x, 50, assume_sorted=True)
    assert (np.asarray(general) == np.asarray(fast)).all()
    dup = jnp.asarray([[3, 3, 5, EMPTY]], jnp.int32)   # sorted, duplicated
    assert (np.asarray(BS.pack_bitset(dup, 40, assume_sorted=True)) ==
            np.asarray(BS.pack_bitset(dup, 40))).all()
    assert int(BS.pack_bitset(dup, 40, assume_sorted=True)[0, 0]) == (
        (1 << 3) | (1 << 5))
    a, b = mksets(rng, 5, 10, 40), mksets(rng, 5, 10, 40)
    cand = jnp.stack([mksets(rng, 3, 10, 40) for _ in range(5)])
    exp = R.fused_triple_stats(a, b, cand)
    got = BS.fused_triple_stats(a, b, cand, n_bits=40, assume_sorted=True)
    for g, e in zip(got, exp):
        assert (np.asarray(g) == np.asarray(e)).all()


def test_bitset_unfused_ops_match_ref():
    rng = np.random.default_rng(11)
    a, b = mksets(rng, 8, 10, 40), mksets(rng, 8, 10, 40)
    cand = jnp.stack([mksets(rng, 3, 10, 40) for _ in range(8)])
    assert (np.asarray(BS.pair_intersect_count(a, b, n_bits=40)) ==
            np.asarray(R.pair_intersect_count(a, b))).all()
    assert (np.asarray(BS.stack_pair_intersect_count(a, cand, n_bits=40)) ==
            np.asarray(R.stack_pair_intersect_count(a, cand))).all()
    assert (np.asarray(BS.triple_intersect_count(a, b, cand, n_bits=40)) ==
            np.asarray(R.triple_intersect_count(a, b, cand))).all()


# ------------------------------------------------------------ backend rules
def test_resolve_backend_rules():
    assert kops.resolve_backend("pallas") == "pallas"
    assert kops.resolve_backend("bitset") == "bitset"
    # auto: tile must outweigh pack + words (PACK_COST model)
    assert kops.resolve_backend(None, c=256, n_bits=8192) == "bitset"
    assert kops.resolve_backend(None, c=8, n_bits=32) != "bitset"
    assert kops.resolve_backend(None, c=128, n_bits=1 << 20) != "bitset"
    # idempotent: a concrete choice survives nested resolves
    assert kops.resolve_backend(
        kops.resolve_backend(None, c=256, n_bits=8192),
        c=8, n_bits=1 << 20) == "bitset"
    with pytest.raises(ValueError):
        kops.resolve_backend("cuda")


def test_bitset_requires_n_bits():
    a = jnp.zeros((2, 4), jnp.int32)
    cand = jnp.zeros((2, 1, 4), jnp.int32)
    with pytest.raises(ValueError, match="n_bits"):
        kops.fused_triple_stats(a, a, cand, backend="bitset")


def test_membership_rejects_bitset():
    # per-element output has no bitset lowering — must fail loud, not
    # silently serve the xla result
    a = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(ValueError, match="bitset"):
        kops.membership(a, a, backend="bitset")


# ------------------------------------------------------------- hypothesis
# guarded import (NOT module-level importorskip: that would skip the
# deterministic sweeps above too when hypothesis is absent)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def fused_case(draw):
        n = draw(st.integers(1, 6))
        k = draw(st.integers(1, 4))
        c = draw(st.integers(1, 20))
        univ = draw(st.integers(1, 70))
        rows = draw(st.lists(
            st.lists(st.integers(0, univ - 1) | st.just(EMPTY),
                     min_size=c, max_size=c),
            min_size=n * (k + 2), max_size=n * (k + 2)))
        arr = np.asarray(rows, np.int32).reshape(n, k + 2, c)
        return (jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1]),
                jnp.asarray(arr[:, 2:]), univ)

    @settings(max_examples=40, deadline=None)
    @given(fused_case())
    def test_fused_property(case):
        a, b, cand, univ = case
        assert_fused_matches(a, b, cand, univ)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 200), st.data())
    def test_pack_bitset_property(n_bits, data):
        c = data.draw(st.integers(1, 16))
        vals = data.draw(st.lists(
            st.integers(0, n_bits - 1) | st.just(EMPTY),
            min_size=c, max_size=c))
        x = jnp.asarray([vals], jnp.int32)
        packed = np.asarray(BS.pack_bitset(x, n_bits))[0]
        want = {v for v in vals if v != EMPTY}
        got = {w * 32 + t for w in range(len(packed))
               for t in range(32) if (int(packed[w]) >> t) & 1}
        assert got == want
else:
    def test_fused_property():
        pytest.skip("hypothesis not installed")

    def test_pack_bitset_property():
        pytest.skip("hypothesis not installed")
