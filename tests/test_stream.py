"""Streaming evolution engine (core/stream.py): event-log scheduling and
end-to-end count exactness — N scheduler batches through ``run_stream`` must
equal a full static recount in all three triad modes, including the
temporal retention-window (expiry) path.

Tests sharing a (batch, n_steps, log capacity, bounds) signature reuse one
XLA scan compilation — keep signatures aligned when adding cases, the
suite's wall time is compile-dominated."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import motifs
from repro.core import stream as S
from repro.core.store import EMPTY
from repro.hypergraph import generators as GEN

V, MAXC, MAXD, MAXR, CHUNK = 18, 8, 32, 127, 512


def _empty_hg():
    return H.from_lists([], num_vertices=V, max_edges=128, max_card=MAXC,
                        max_vdeg=64, min_capacity=4096)


def _run(events, counts, batch=8, n_steps=None, capacity=None, **kw):
    log = S.log_from_events(events, max_card=MAXC, capacity=capacity)
    st = S.make_stream(_empty_hg(), log, counts)
    if n_steps is None:
        n_steps = S.plan_steps(events, batch, expiry=kw.get("expiry"))
    return S.run_stream(st, n_steps=n_steps, batch=batch, **kw)


_EDGE_KW = dict(mode="edge", max_deg=MAXD, max_region=MAXR, chunk=CHUNK)


def test_edge_mode_matches_recount():
    events = GEN.event_stream(40, V, seed=1, max_card=6, insert_frac=0.7)
    st = _run(events, jnp.zeros(motifs.NUM_CLASSES, jnp.int32), **_EDGE_KW)
    assert int(st.error) == 0
    assert int(st.log.n_pending) == 0
    ref = BL.mochy_static(st.hg, max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(ref)).all()
    assert int(st.counts.sum()) > 0


def test_temporal_mode_matches_recount():
    events = GEN.event_stream(40, V, seed=2, max_card=6, max_dt=4)
    W = 50
    st = _run(events, jnp.zeros(motifs.NUM_TEMPORAL, jnp.int32),
              mode="temporal", max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
              window=W)
    assert int(st.error) == 0
    ref = BL.thyme_static(st.hg, st.times, W, max_deg=MAXD,
                          max_region=MAXR, chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(ref)).all()


def test_temporal_expiry_matches_recount():
    """Sliding retention window: aged-out inserts re-enter as deletions and
    the final live set + counts still match a from-scratch recount."""
    events = GEN.event_stream(50, V, seed=3, max_card=6, insert_frac=0.85,
                              max_dt=4)
    W, EXP = 60, 40
    st = _run(events, jnp.zeros(motifs.NUM_TEMPORAL, jnp.int32),
              mode="temporal", max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
              window=W, expiry=EXP)
    assert int(st.error) == 0
    assert int(st.log.n_pending) == 0
    ref = BL.thyme_static(st.hg, st.times, W, max_deg=MAXD,
                          max_region=MAXR, chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(ref)).all()
    # every surviving edge is inside the retention window, and expiry
    # actually fired (more inserts than survivors + explicit deletes)
    t_final = max(t for t, _, _ in events)
    lt = np.asarray(st.live_t)
    live_times = lt[lt != np.iinfo(np.int32).max]
    assert (live_times > t_final - EXP).all()
    n_ins = sum(1 for _, k, _ in events if k == "ins")
    n_del = sum(1 for _, k, _ in events if k == "del")
    assert len(live_times) < n_ins - n_del


def test_vertex_mode_matches_recount():
    events = GEN.event_stream(35, V, seed=4, max_card=6)
    st = _run(events, jnp.zeros(3, jnp.int32),
              mode="vertex", max_nb=32, max_region=64, chunk=128, v_total=V)
    assert int(st.error) == 0
    ref = BL.stathyper_static(st.hg, V, max_nb=32, max_region=V, chunk=128)
    assert (np.asarray(st.counts) == np.asarray(ref)).all()


def test_scheduler_semantics():
    """Barrier / malformed / duplicate-delete handling, all through one
    shared (capacity=8, batch=8, n_steps=2) compilation."""
    fixed = dict(capacity=8, batch=8, n_steps=2, **_EDGE_KW)
    zeros = jnp.zeros(motifs.NUM_CLASSES, jnp.int32)

    # a DEL whose INS sits in the same batch is deferred, not dropped
    events = [(0, "ins", [0, 1, 2]), (1, "ins", [1, 2, 3]), (2, "del", 0)]
    assert S.plan_steps(events, 8) == 2      # barrier splits the batch
    st = _run(events, zeros, **fixed)
    assert int(st.error) == 0
    assert int(st.hg.h2v.n_live) == 1        # edge 0 inserted then deleted
    ref = BL.mochy_static(st.hg, max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(ref)).all()

    # a DEL preceding its INS in the log is dropped with the sticky error
    st = _run([(0, "del", 1), (1, "ins", [0, 1, 2])], zeros, **fixed)
    assert int(st.error) == S.ERR_MALFORMED_DEL
    assert [e.name for e in S.decode_errors(st)] == ["malformed-delete"]
    assert int(st.hg.h2v.n_live) == 1        # the insert still applied

    # double delete of one edge is a no-op (second resolves to EMPTY /
    # same-batch duplicate is deduped)
    events = [(0, "ins", [0, 1, 2]), (1, "ins", [2, 3, 4]),
              (2, "del", 0), (3, "del", 0)]
    st = _run(events, zeros, **fixed)
    assert int(st.error) == 0
    assert int(st.hg.h2v.n_live) == 1


def test_push_overflow_sets_sticky_error():
    log = S.make_event_log(4, MAXC)
    t = jnp.arange(6, dtype=jnp.int32)
    kind = jnp.zeros(6, jnp.int32)
    lists = jnp.full((6, MAXC), EMPTY, jnp.int32).at[:, 0].set(1).at[:, 1].set(2)
    cards = jnp.full(6, 2, jnp.int32)
    ref = jnp.full(6, EMPTY, jnp.int32)
    log = S.push_events(log, t, kind, lists, cards, ref, jnp.ones(6, bool))
    assert int(log.error) == S.ERR_LOG_OVERFLOW
    assert int(log.tail) == 4                # accepted prefix only


def _push_host(log, chunk_ev):
    n = len(chunk_ev)
    t = jnp.asarray([e[0] for e in chunk_ev], jnp.int32)
    kind = jnp.asarray([S.DEL if e[1] == "del" else S.INS for e in chunk_ev])
    lists = np.full((n, MAXC), EMPTY, np.int32)
    cards = np.zeros(n, np.int32)
    ref = np.full(n, EMPTY, np.int32)
    for i, (_, k, payload) in enumerate(chunk_ev):
        if k == "ins":
            e = sorted(payload)
            lists[i, : len(e)] = e
            cards[i] = len(e)
        else:
            ref[i] = payload
    return S.push_events(log, t, kind, jnp.asarray(lists), jnp.asarray(cards),
                         jnp.asarray(ref), jnp.ones(n, bool))


def test_ring_reuse_and_slot_collision():
    """Online usage: a log smaller than the stream, drained and refilled.
    Ring slots are reused safely while every edge dies within ``capacity``
    subsequent events; an edge outliving its slot raises the sticky
    collision flag instead of silently corrupting bookkeeping.  Both halves
    share one (capacity=8, batch=4, n_steps=1) compilation."""
    kw = dict(batch=4, **_EDGE_KW)
    events = []
    for g in range(6):                       # lifetime ≤ 3 events < capacity 8
        i = len(events)
        events.append((4 * g, "ins", [g % V, (g + 1) % V, (g + 2) % V]))
        events.append((4 * g + 1, "ins", [(g + 1) % V, (g + 3) % V, (g + 5) % V]))
        events.append((4 * g + 2, "del", i))
        events.append((4 * g + 3, "del", i + 1))
    st = S.make_stream(_empty_hg(), S.make_event_log(8, MAXC),
                       jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    for lo in range(0, len(events), 8):
        st = dataclasses.replace(st, log=_push_host(st.log, events[lo:lo + 8]))
        while int(st.log.n_pending) > 0:
            st = S.run_stream(st, n_steps=1, **kw)
    assert int(st.error) == 0
    assert int(st.hg.h2v.n_live) == 0        # every insert was deleted
    ref_counts = BL.mochy_static(st.hg, max_deg=MAXD, max_region=MAXR,
                                 chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(ref_counts)).all()

    # collision: 8 inserts that never die, wrapped onto their live slots
    st = S.make_stream(_empty_hg(), S.make_event_log(8, MAXC),
                       jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    first = [(i, "ins", [i, i + 1, i + 2]) for i in range(8)]
    st = dataclasses.replace(st, log=_push_host(st.log, first))
    for _ in range(2):
        st = S.run_stream(st, n_steps=1, **kw)
    assert int(st.error) == 0
    second = [(8 + i, "ins", [i, i + 3, i + 6]) for i in range(8)]
    st = dataclasses.replace(st, log=_push_host(st.log, second))
    for _ in range(2):
        st = S.run_stream(st, n_steps=1, **kw)
    assert int(st.error) == S.ERR_SLOT_COLLISION
    assert [e.name for e in S.decode_errors(st)] == ["ring-slot-collision"]


def test_expiry_quota_not_consumed_by_explicit_deletes():
    """Regression: expiry candidates are selected after this batch's
    explicit deletes, so deleted slots cannot waste the per-step expiry
    quota — plan_steps' drain guarantee depends on it."""
    events = [(t, "ins", [t % V, (t + 1) % V, (t + 2) % V])
              for t in range(1, 6)] + [(30, "del", 0)]
    EXP = 10
    st = _run(events, jnp.zeros(motifs.NUM_CLASSES, jnp.int32), batch=2,
              mode="edge", max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
              expiry=EXP)
    assert int(st.error) == 0
    assert int(st.log.n_pending) == 0
    lt = np.asarray(st.live_t)
    live_times = lt[lt != np.iinfo(np.int32).max]
    assert len(live_times) == 0              # everything expired or deleted


def test_slot_reuse_within_one_batch_is_not_a_collision():
    """Regression: a ring slot freed by a delete coalesced into the same
    batch as the insert that reuses it must not raise the collision flag."""
    st = S.make_stream(_empty_hg(), S.make_event_log(4, MAXC),
                       jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    kw = dict(batch=4, **_EDGE_KW)
    st = dataclasses.replace(st, log=_push_host(st.log, [(0, "ins", [0, 1, 2])]))
    st = S.run_stream(st, n_steps=1, **kw)   # consume seq 0 (slot 0)
    more = [(1, "del", 0), (2, "ins", [1, 2, 3]), (3, "ins", [2, 3, 4]),
            (4, "ins", [3, 4, 5])]           # seq 4 wraps onto freed slot 0
    st = dataclasses.replace(st, log=_push_host(st.log, more))
    st = S.run_stream(st, n_steps=1, **kw)
    assert int(st.log.n_pending) == 0
    assert int(st.error) == 0
    assert int(st.hg.h2v.n_live) == 3


@pytest.mark.slow
def test_edge_mode_batch_size_invariance():
    """Same stream, different coalescing — identical final counts/graph."""
    events = GEN.event_stream(30, V, seed=5, max_card=6)
    finals = []
    for b in (2, 16):
        st = _run(events, jnp.zeros(motifs.NUM_CLASSES, jnp.int32), batch=b,
                  **_EDGE_KW)
        assert int(st.error) == 0
        finals.append((np.asarray(st.counts), int(st.hg.h2v.n_live)))
    assert (finals[0][0] == finals[1][0]).all()
    assert finals[0][1] == finals[1][1]


@pytest.mark.slow
def test_plan_steps_matches_device_drain():
    """The host scheduler simulation and the device scheduler agree: after
    plan_steps steps the log is drained, and one step earlier it is not."""
    events = GEN.event_stream(30, V, seed=9, max_card=6, insert_frac=0.65)
    B = 4
    n = S.plan_steps(events, B)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(_empty_hg(), log, jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    st_partial = S.run_stream(st, n_steps=n - 1, batch=B, **_EDGE_KW)
    assert int(st_partial.log.n_pending) > 0
    st_full = S.run_stream(st_partial, n_steps=1, batch=B, **_EDGE_KW)
    assert int(st_full.log.n_pending) == 0
