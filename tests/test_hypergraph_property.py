"""Property test: ESCHER two-way hypergraph == plain Python dict-of-sets
model under random op sequences (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hypergraph as H
from repro.core.store import EMPTY, read_dense

NV = 12
MAXC = 8
BATCH = 3  # fixed shapes -> one jit trace for the whole suite


def _pad_insert(edges):
    nl = np.full((BATCH, MAXC), EMPTY, np.int32)
    nc = np.zeros(BATCH, np.int32)
    mask = np.zeros(BATCH, bool)
    for i, e in enumerate(edges[:BATCH]):
        nl[i, : len(e)] = sorted(e)
        nc[i] = len(e)
        mask[i] = True
    return jnp.asarray(nl), jnp.asarray(nc), jnp.asarray(mask)


def _pad_del(ranks):
    d = np.zeros(BATCH, np.int32)
    m = np.zeros(BATCH, bool)
    for i, r in enumerate(ranks[:BATCH]):
        d[i] = r
        m[i] = True
    return jnp.asarray(d), jnp.asarray(m)


edge_strategy = st.lists(
    st.integers(0, NV - 1), min_size=2, max_size=4, unique=True)

op_strategy = st.one_of(
    st.tuples(st.just("del"), st.lists(st.integers(0, 30), min_size=1, max_size=BATCH)),
    st.tuples(st.just("ins"), st.lists(edge_strategy, min_size=1, max_size=BATCH)),
    st.tuples(st.just("vmod"),
              st.lists(st.tuples(st.integers(0, 30), st.integers(0, NV - 1),
                                 st.booleans()), min_size=1, max_size=BATCH)),
)


@settings(max_examples=15, deadline=None)
@given(
    init=st.lists(edge_strategy, min_size=2, max_size=6),
    ops=st.lists(op_strategy, min_size=1, max_size=5),
)
def test_escher_matches_python_model(init, ops):
    # dedupe initial edges (hypergraphs of distinct hyperedges)
    seen, edges = set(), []
    for e in init:
        t = tuple(sorted(e))
        if t not in seen:
            seen.add(t)
            edges.append(sorted(e))
    hg = H.from_lists(edges, num_vertices=NV, max_edges=32, max_card=MAXC,
                      max_vdeg=64, slack=4.0)
    model = {i: set(e) for i, e in enumerate(edges)}

    for kind, payload in ops:
        if kind == "del":
            live = sorted(model)
            ranks = [live[r % len(live)] for r in payload] if live else []
            ranks = list(dict.fromkeys(ranks))
            if not ranks:
                continue
            d, m = _pad_del(ranks)
            hg = H.delete_hyperedges(hg, d, m)
            for r in ranks[:BATCH]:
                model.pop(r, None)
        elif kind == "ins":
            nl, nc, mask = _pad_insert(payload)
            hg, new_ranks = H.insert_hyperedges(hg, nl, nc, mask)
            for i, e in enumerate(payload[:BATCH]):
                model[int(new_ranks[i])] = set(e)
        else:  # vmod
            live = sorted(model)
            if not live:
                continue
            hids, vids, ins = [], [], []
            for h, v, is_ins in payload:
                hids.append(live[h % len(live)])
                vids.append(v)
                ins.append(is_ins)
            hh = np.zeros(BATCH, np.int32)
            vv = np.zeros(BATCH, np.int32)
            ii = np.zeros(BATCH, bool)
            mm = np.zeros(BATCH, bool)
            for i in range(min(len(hids), BATCH)):
                hh[i], vv[i], ii[i], mm[i] = hids[i], vids[i], ins[i], True
            hg = H.apply_vertex_updates(hg, jnp.asarray(hh), jnp.asarray(vv),
                                        jnp.asarray(ii), jnp.asarray(mm))
            for i in range(min(len(hids), BATCH)):
                s = model[hids[i]]
                if ii[i] and len(s) < MAXC:
                    s.add(vids[i])
                elif not ii[i]:
                    s.discard(vids[i])

        assert H.to_python(hg) == model
        # v2h mapping consistent with h2v (two-way invariant)
        for v in range(NV):
            row = np.asarray(read_dense(hg.v2h, jnp.array([v])))[0]
            got = set(row[row != EMPTY].tolist())
            exp = {h for h, s in model.items() if v in s}
            assert got == exp, (v, got, exp)
