"""Elastic store (core/elastic.py, DESIGN.md §8): growth and compaction
preserve every live list and every rank bit-exactly; the segmented
``run_stream(auto_grow=True)`` driver turns a minimally-sized store into an
open-ended one whose final state is bit-identical to a pre-sized run; and
the sticky error bitmask decodes to (flag, batch) on the host.

Regression surface called out in ISSUE 5: a Case-2 overflow chain must
survive delete-then-reinsert block reuse, and ``compact_store`` must
preserve ``read_sorted`` / ``dedupe_sorted`` order exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import blockmgr as bm
from repro.core import elastic as E
from repro.core import hypergraph as H
from repro.core import motifs
from repro.core import ops
from repro.core import stream as S
from repro.core import triads as T
from repro.core.store import (
    EMPTY, ERR_CAPACITY, ERR_RANKS, dedupe_sorted, read_dense, read_sorted)
from repro.hypergraph import generators as GEN

V, MAXC, MAXD, MAXR, CHUNK = 18, 8, 16, 63, 64


def _hg(n_edges=16, seed=0, **kw):
    edges = GEN.random_hypergraph(n_edges, V, profile="coauth", max_card=6,
                                  seed=seed, skew=0.3)
    kw.setdefault("max_edges", 2 * n_edges)
    kw.setdefault("max_card", MAXC)
    kw.setdefault("slack", 2.0)
    return H.from_lists(edges, num_vertices=V, **kw)


def _insert(hg, members, max_card=None):
    mc = max_card or hg.h2v.max_card
    nl = np.full((1, mc), EMPTY, np.int32)
    nl[0, : len(members)] = sorted(members)
    return H.insert_hyperedges(hg, jnp.asarray(nl),
                               jnp.asarray([len(members)], np.int32),
                               jnp.ones(1, bool))


def _chained_hg():
    """A hypergraph holding one Case-2 chained block: delete a small edge,
    reinsert a big one into its freed (too small) primary."""
    hg = _hg(6, max_card=16, granule=8)
    hg = H.delete_hyperedges(hg, jnp.array([1]), jnp.ones(1, bool))
    hg, ranks = _insert(hg, list(range(2, 14)))          # card 12 > 7 usable
    assert int(ranks[0]) == 1
    idx = int(bm.cbt_index(jnp.int32(1), hg.h2v.mgr.height))
    assert int(hg.h2v.mgr.addr1[idx]) >= 0               # chain exists
    assert int(hg.h2v.error) == 0
    return hg


# ------------------------------------------------------------------ growth
def test_grow_preserves_reads_ranks_and_counts():
    hg = _chained_hg()
    n = hg.n_edge_slots
    before = np.asarray(read_dense(hg.h2v, jnp.arange(n)))
    counts0 = BL.mochy_static(hg, max_deg=MAXD, max_region=MAXR, chunk=CHUNK)

    grown = E.grow_hypergraph(
        hg, h2v_capacity=2 * hg.h2v.capacity, h2v_levels=1,
        v2h_capacity=2 * hg.v2h.capacity)
    assert grown.h2v.capacity == 2 * hg.h2v.capacity
    assert grown.h2v.mgr.height == hg.h2v.mgr.height + 1
    assert grown.n_edge_slots == 2 * n + 1
    after = np.asarray(read_dense(grown.h2v, jnp.arange(n)))
    assert (before == after).all()                        # ranks stable
    counts1 = BL.mochy_static(grown, max_deg=MAXD, max_region=MAXR,
                              chunk=CHUNK)
    assert (np.asarray(counts0) == np.asarray(counts1)).all()
    # the added rank space is dummy (not present) until Case 3 activates it
    assert int(grown.h2v.n_live) == int(hg.h2v.n_live)


def test_grow_tree_then_insert_uses_new_rank_space():
    hg = _hg(6, max_edges=7, granule=8)                   # height 3: 7 slots
    # exhaust the fresh-rank space: 6 used, 1 left
    hg, _ = _insert(hg, [0, 1])
    st, ranks = ops.insert_hyperedges(
        hg.h2v,
        jnp.full((1, MAXC), EMPTY, jnp.int32).at[0, :2].set(jnp.array([2, 3])),
        jnp.asarray([2], np.int32), jnp.ones(1, bool))
    assert int(st.error) & ERR_RANKS                      # 8th edge: no slot
    grown = E.grow_hypergraph(hg, h2v_levels=1,
                              h2v_capacity=2 * hg.h2v.capacity)
    grown, ranks = _insert(grown, [2, 3])
    assert int(ranks[0]) == 7                             # first new rank
    assert int(grown.h2v.error) == 0


def test_grow_vertex_universe_registers_new_ids():
    hg = _hg(6)
    nv = hg.num_vertices
    grown = E.grow_hypergraph(hg, v2h_levels=1,
                              v2h_capacity=2 * hg.v2h.capacity)
    assert grown.num_vertices == 2 * nv + 1
    # an edge over brand-new vertex ids inserts cleanly, two-way
    vids = [nv + 1, nv + 3, 2 * nv - 1]
    grown, ranks = _insert(grown, vids)
    assert int(grown.h2v.error) == 0 and int(grown.v2h.error) == 0
    got = np.asarray(read_dense(grown.h2v, ranks))
    assert sorted(got[got != EMPTY].tolist()) == sorted(vids)
    back = np.asarray(read_dense(grown.v2h, jnp.asarray(vids)))
    assert all(int(ranks[0]) in row[row != EMPTY].tolist() for row in back)


def test_grow_register_ranks_does_not_resurrect_deleted():
    """Regression (review finding): vertex-universe growth registers only
    never-used ranks — a deleted rank must stay in the Case-1 free pool,
    not come back to life with its stale pre-delete contents."""
    hg = _hg(6)
    # delete a v2h rank through the same vertical path h2v uses
    st = ops.delete_hyperedges(hg.v2h, jnp.array([3]), jnp.ones(1, bool))
    hg = H.Hypergraph(h2v=hg.h2v, v2h=st)
    avail_before = int(st.mgr.root_avail)
    grown = E.grow_hypergraph(hg, v2h_levels=1,
                              v2h_capacity=2 * hg.v2h.capacity)
    g = grown.v2h
    idx = int(bm.cbt_index(jnp.int32(3), g.mgr.height))
    assert int(g.mgr.present[idx]) == 0          # still dead
    assert int(g.mgr.deleted[idx]) == 1          # still reusable
    assert int(g.mgr.root_avail) == avail_before
    row = np.asarray(read_dense(g, jnp.array([3])))[0]
    assert (row == EMPTY).all()                  # no stale contents served


def test_grow_store_rejects_shrink():
    hg = _hg(4)
    with pytest.raises(ValueError):
        E.grow_store(hg.h2v, capacity=hg.h2v.capacity // 2)


# -------------------------------------------------------------- compaction
def test_compact_preserves_read_sorted_and_dedupe_sorted():
    hg = _chained_hg()
    n = hg.n_edge_slots
    ranks = jnp.arange(n)
    dense0 = np.asarray(read_dense(hg.h2v, ranks))
    sorted0 = np.asarray(read_sorted(hg.h2v, ranks))
    dedup0 = np.asarray(dedupe_sorted(read_dense(hg.h2v, ranks)))

    cs = E.compact_store(hg.h2v)
    assert (np.asarray(read_dense(cs, ranks)) == dense0).all()
    assert (np.asarray(read_sorted(cs, ranks)) == sorted0).all()
    assert (np.asarray(dedupe_sorted(read_dense(cs, ranks))) == dedup0).all()
    # chains folded into right-sized primaries; metadata slots maintained
    idx = bm.cbt_index(ranks, cs.mgr.height)
    assert (np.asarray(cs.mgr.addr1[idx]) < 0).all()
    a0 = np.asarray(cs.mgr.addr0[idx])
    c0 = np.asarray(cs.mgr.cap0[idx])
    live = np.asarray(cs.mgr.present[idx]) == 1
    A = np.asarray(cs.A)
    for s, c in zip(a0[live], c0[live]):
        assert A[s + c - 1] == -1                         # END metadata


def test_compact_reclaims_leaked_overflow_tail():
    """Horizontal regrowth leaks replaced overflow blocks (documented trade
    in ops._write_rows); compaction gets the slots back."""
    hg = H.from_lists([[0, 1, 2], [3, 4], [5, 6, 7]], num_vertices=V,
                      max_edges=8, max_card=16, granule=8, slack=4.0)
    # push edge 0 from card 3 to 15: the first overflow (8 slots, usable 7,
    # total 14) is outgrown at card 15 and _write_rows replaces it, leaking
    # the old block — the documented bump-allocator trade
    for v in range(3, 15):
        hg = H.apply_vertex_updates(hg, jnp.array([0]), jnp.array([v]),
                                    jnp.array([True]), jnp.ones(1, bool))
    assert int(hg.h2v.error) == 0
    stats = E.store_stats(hg.h2v)
    assert stats["used"] > stats["live"]                  # leak exists
    cs = E.compact_store(hg.h2v)
    stats2 = E.store_stats(cs)
    assert stats2["used"] == stats2["live"] < stats["used"]
    assert int(cs.free_ptr) < int(hg.h2v.free_ptr)


def test_case2_chain_survives_delete_then_reinsert_reuse():
    """Regression (ISSUE 5): delete a chained edge, reinsert into the freed
    node — Case-1 reuse must see the chain capacity and the read must
    follow the chain, before and after compaction."""
    hg = _chained_hg()
    # delete the chained edge, reinsert something that still needs a chain
    hg = H.delete_hyperedges(hg, jnp.array([1]), jnp.ones(1, bool))
    big2 = list(range(20, 31))                            # card 11 -> chained
    hg, ranks = _insert(hg, big2)
    assert int(ranks[0]) == 1                             # same node reused
    assert int(hg.h2v.error) == 0
    got = np.asarray(read_dense(hg.h2v, ranks))
    assert sorted(got[got != EMPTY].tolist()) == big2

    # again, with a compaction between delete and reinsert: the freed node
    # is stripped to zero capacity and reuse allocates fresh (chain path)
    hg = H.delete_hyperedges(hg, jnp.array([1]), jnp.ones(1, bool))
    hg = H.Hypergraph(h2v=E.compact_store(hg.h2v), v2h=hg.v2h)
    hg, ranks = _insert(hg, big2)
    assert int(ranks[0]) == 1
    assert int(hg.h2v.error) == 0
    got = np.asarray(read_dense(hg.h2v, ranks))
    assert sorted(got[got != EMPTY].tolist()) == big2


# ------------------------------------------------------------ decode_errors
def test_decode_errors_names_flag_and_batch():
    hg = H.from_lists([], num_vertices=V, max_edges=4, max_card=MAXC,
                      max_vdeg=8, granule=8, slack=1.0)   # 8-slot h2v
    events = GEN.event_stream(12, V, seed=7, max_card=5, insert_frac=1.0)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(hg, log, jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    st = S.run_stream(st, n_steps=6, batch=2, mode="edge", max_deg=MAXD,
                      max_region=MAXR, chunk=CHUNK)       # no auto_grow
    assert int(st.error) != 0
    errs = S.decode_errors(st)
    names = {e.name for e in errs}
    assert "store-capacity-overflow" in names
    by_name = {e.name: e for e in errs}
    cap = by_name["store-capacity-overflow"]
    assert cap.flag == ERR_CAPACITY
    assert 1 <= cap.epoch <= 6                            # which batch
    # clean runs decode to nothing
    assert S.decode_errors(
        S.make_stream(hg, log, jnp.zeros(motifs.NUM_CLASSES, jnp.int32))) == []


# ------------------------------------------------- auto_grow segmented scan
def _stream_events(n=28, seed=5):
    return GEN.event_stream(n, V, seed=seed, max_card=5, insert_frac=0.85)


def _run_events(hg0, events, *, auto_grow, segment=2, batch=4, **kw):
    steps = S.plan_steps(events, batch)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(hg0, log, kw.pop("counts0"))
    return S.run_stream(st, n_steps=steps, batch=batch, max_deg=MAXD,
                        max_region=MAXR, chunk=CHUNK, auto_grow=auto_grow,
                        segment=segment, **kw)


def test_auto_grow_matches_presized_bit_identically():
    """The acceptance contract: a stream started at minimal capacity grows
    >= 8x under ``auto_grow`` and its final state — counts, epoch, dirty
    maps, live set — is bit-identical to a run pre-sized to the final
    capacity (fig21 measures the same at benchmark scale)."""
    events = _stream_events()
    tiny = H.from_lists([], num_vertices=V, max_edges=4, max_card=MAXC,
                        max_vdeg=16, granule=8, slack=1.0)
    cap0 = tiny.h2v.capacity
    zeros = jnp.zeros(motifs.NUM_CLASSES, jnp.int32)
    st = _run_events(tiny, events, auto_grow=True, mode="edge",
                     counts0=zeros)
    assert int(st.error) == 0, S.decode_errors(st)
    assert int(st.log.n_pending) == 0
    assert st.hg.h2v.capacity >= 8 * cap0                 # real growth
    assert st.hg.h2v.mgr.height > tiny.h2v.mgr.height     # tree grew too

    big = H.from_lists([], num_vertices=V, max_edges=st.hg.n_edge_slots,
                       max_card=MAXC, max_vdeg=16, granule=8,
                       min_capacity=max(st.hg.h2v.capacity,
                                        st.hg.v2h.capacity))
    ref = _run_events(big, events, auto_grow=False, mode="edge",
                      counts0=zeros)
    assert int(ref.error) == 0
    assert (np.asarray(st.counts) == np.asarray(ref.counts)).all()
    assert int(st.epoch) == int(ref.epoch)
    assert H.to_python(st.hg) == H.to_python(ref.hg)
    n = min(st.dirty_epoch.shape[0], ref.dirty_epoch.shape[0])
    assert (np.asarray(st.dirty_epoch[:n])
            == np.asarray(ref.dirty_epoch[:n])).all()
    # and the maintained histogram matches a from-scratch recount
    recount = BL.mochy_static(st.hg, max_deg=MAXD, max_region=MAXR,
                              chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(recount)).all()


def test_auto_grow_temporal_expiry_reaches_steady_state():
    """Temporal mode with a retention window on a tiny store: expiry keeps
    the live set bounded while capacity grows only as far as fragmentation
    demands (compaction folds reclaimed space back in)."""
    events = GEN.event_stream(30, V, seed=11, max_card=5, insert_frac=0.9,
                              max_dt=3)
    tiny = H.from_lists([], num_vertices=V, max_edges=8, max_card=MAXC,
                        max_vdeg=16, granule=8, slack=1.0)
    steps = S.plan_steps(events, 4, expiry=20)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(tiny, log, jnp.zeros(motifs.NUM_TEMPORAL, jnp.int32))
    st = S.run_stream(st, n_steps=steps, batch=4, mode="temporal",
                      max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
                      window=25, expiry=20, auto_grow=True, segment=2)
    assert int(st.error) == 0, S.decode_errors(st)
    assert int(st.log.n_pending) == 0
    ref = BL.thyme_static(st.hg, st.times, 25, max_deg=MAXD,
                          max_region=MAXR, chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(ref)).all()


def test_auto_grow_vertex_mode_matches_recount():
    events = GEN.event_stream(22, V, seed=13, max_card=5, insert_frac=0.8)
    tiny = H.from_lists([], num_vertices=V, max_edges=4, max_card=MAXC,
                        max_vdeg=16, granule=8, slack=1.0)
    steps = S.plan_steps(events, 4)
    log = S.log_from_events(events, max_card=MAXC)
    st = S.make_stream(tiny, log, jnp.zeros(3, jnp.int32))
    st = S.run_stream(st, n_steps=steps, batch=4, mode="vertex", max_nb=32,
                      max_region=MAXR, chunk=CHUNK, v_total=V,
                      auto_grow=True, segment=2)
    assert int(st.error) == 0, S.decode_errors(st)
    ref = BL.stathyper_static(st.hg, V, max_nb=32, max_region=V, chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(ref)).all()


def test_auto_grow_vertex_universe_from_out_of_range_vids():
    """Regression (review finding): an event whose vertex ids exceed the
    store's universe must trip the growable ERR_RANKS bit on v2h (not
    silently corrupt another vertex's bookkeeping), and auto_grow must
    answer it by widening the vertex universe until the ids fit."""
    events = [(t, "ins", [t % 5, (t + 1) % 5 + 5, 12 + (t % 9)])
              for t in range(10)]                    # vids up to 20
    small = H.from_lists([], num_vertices=7, max_edges=16, max_card=MAXC,
                         max_vdeg=16, granule=8, slack=1.0,
                         min_capacity=1024)          # universe: 7 vertices
    zeros = jnp.zeros(motifs.NUM_CLASSES, jnp.int32)

    # fixed-capacity path: sticky growable bit, decoded by name
    st0 = _run_events(small, events, auto_grow=False, mode="edge",
                      counts0=zeros)
    assert int(st0.error) & ERR_RANKS
    assert "rank-space-exhausted" in {e.name for e in S.decode_errors(st0)}

    st = _run_events(small, events, auto_grow=True, mode="edge",
                     counts0=zeros)
    assert int(st.error) == 0, S.decode_errors(st)
    assert st.hg.num_vertices >= 21                  # universe grew to fit
    ref = BL.mochy_static(st.hg, max_deg=MAXD, max_region=MAXR, chunk=CHUNK)
    assert (np.asarray(st.counts) == np.asarray(ref)).all()
    assert H.to_python(st.hg) == {
        r: set(e[2]) for r, e in enumerate(events)}


def test_tree_padding_vids_are_real_vertices():
    """Regression (review finding): ``num_vertices`` reports the padded
    tree size (2^h - 1), so vids in [requested, 2^h - 1) must behave as
    registered vertices — full two-way duality, not silently-invisible
    nodes that pass the in-universe guard."""
    hg = H.from_lists([[0, 1, 2]], num_vertices=18, max_edges=8,
                      max_card=MAXC, granule=8, slack=2.0,
                      min_capacity=1024)
    assert hg.num_vertices == 31                     # padded universe
    hg, ranks = _insert(hg, [20, 25, 30])            # gap vids
    assert int(hg.h2v.error) == 0 and int(hg.v2h.error) == 0
    r = int(ranks[0])
    back = np.asarray(read_dense(hg.v2h, jnp.array([20, 25, 30])))
    assert all(r in row[row != EMPTY].tolist() for row in back)
    # the duality holds through delete too
    hg = H.delete_hyperedges(hg, ranks, jnp.ones(1, bool))
    back = np.asarray(read_dense(hg.v2h, jnp.array([20, 25, 30])))
    assert (back == EMPTY).all()
    # and neighbors() sees adjacency through a gap vid
    hg, ra = _insert(hg, [4, 5, 20])
    hg, rb = _insert(hg, [20, 6, 7])
    nb = np.asarray(H.neighbors(hg, ra, 4))[0]
    assert int(rb[0]) in nb.tolist()                 # linked via vertex 20


def test_auto_grow_ceilings_degrade_to_sticky_error():
    """Regression (review finding): a garbage vertex id that would demand
    an absurd universe must cost a decoded rank-space error under the
    growth ceilings — not exponential doubling until OOM."""
    events = [(0, "ins", [0, 1, 2]), (1, "ins", [1, 2, 1_000_000]),
              (2, "ins", [2, 3, 4])]
    small = H.from_lists([], num_vertices=7, max_edges=16, max_card=MAXC,
                         max_vdeg=16, granule=8, slack=1.0,
                         min_capacity=1024)
    log = S.log_from_events(events, max_card=MAXC, capacity=8)
    st = S.make_stream(small, log, jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    st = S.run_stream(st, n_steps=3, batch=4, mode="edge", max_deg=MAXD,
                      max_region=MAXR, chunk=CHUNK, auto_grow=True,
                      segment=1, max_height=6)       # universe cap: 63
    assert st.hg.v2h.mgr.height <= 6                 # no runaway doubling
    assert "rank-space-exhausted" in {e.name for e in S.decode_errors(st)}


def test_auto_grow_does_not_mask_nongrowable_errors():
    """A malformed delete is structural: auto_grow must not retry it away —
    the sticky bit survives with its batch number."""
    hg = H.from_lists([], num_vertices=V, max_edges=64, max_card=MAXC,
                      max_vdeg=32, min_capacity=2048)
    bad = [(0, "del", 1), (1, "ins", [0, 1, 2]), (2, "ins", [2, 3, 4])]
    log = S.log_from_events(bad, max_card=MAXC, capacity=8)
    st = S.make_stream(hg, log, jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    st = S.run_stream(st, n_steps=2, batch=4, mode="edge", max_deg=MAXD,
                      max_region=MAXR, chunk=CHUNK, auto_grow=True,
                      segment=1)
    errs = S.decode_errors(st)
    assert [e.name for e in errs] == ["malformed-delete"]
    assert errs[0].epoch == 1
    assert int(st.hg.h2v.n_live) == 2                     # inserts applied


def test_sharded_auto_grow_parity():
    """distributed lockstep: the sharded auto_grow stream and an explicit
    ``grow_replicated`` store agree bit-identically with single-device."""
    from repro.distributed import triads as DT

    mesh = DT.count_mesh(min(4, len(jax.devices())))
    events = _stream_events(n=20, seed=17)
    zeros = jnp.zeros(motifs.NUM_CLASSES, jnp.int32)

    def run(mesh_):
        tiny = H.from_lists([], num_vertices=V, max_edges=4, max_card=MAXC,
                            max_vdeg=16, granule=8, slack=1.0)
        return _run_events(tiny, events, auto_grow=True, mode="edge",
                           counts0=zeros, mesh=mesh_)

    single, sharded = run(None), run(mesh)
    assert int(single.error) == 0 and int(sharded.error) == 0
    assert (np.asarray(single.counts) == np.asarray(sharded.counts)).all()
    assert single.hg.h2v.capacity == sharded.hg.h2v.capacity

    grown = DT.grow_replicated(
        single.hg, mesh=mesh, h2v_capacity=2 * single.hg.h2v.capacity,
        h2v_levels=1, compact=True)
    reg, m = T.all_live_region(grown, MAXR)
    ref = T.count_triads(grown, reg, m, max_deg=MAXD, chunk=CHUNK)
    got = DT.count_triads_sharded(grown, reg, m, mesh=mesh, max_deg=MAXD,
                                  chunk=CHUNK)
    assert (np.asarray(got) == np.asarray(ref)).all()


# ----------------------------------------------- query service across growth
def test_snapshot_cache_invalidates_across_growth():
    """Growth preserves answers but changes geometry: the cache must miss
    (shape_key) rather than serve through a stale neighbour index, and the
    re-served answers must equal the pre-growth ones."""
    from repro import query

    events = _stream_events(n=24, seed=19)
    tiny = H.from_lists([], num_vertices=V, max_edges=4, max_card=MAXC,
                        max_vdeg=16, granule=8, slack=1.0)
    st = _run_events(tiny, events, auto_grow=True, mode="edge",
                     counts0=jnp.zeros(motifs.NUM_CLASSES, jnp.int32))
    assert int(st.error) == 0

    snap1 = query.of_stream(st)
    cache = query.QueryCache()
    live = np.asarray(st.hg.h2v.mgr.hid)[
        np.asarray(st.hg.h2v.mgr.present) == 1]
    reqs = [query.triads_containing_edge(int(r)) for r in live[:4]]
    ans1 = query.serve(snap1, reqs, max_deg=MAXD, chunk=CHUNK, cache=cache)
    miss1 = cache.misses

    grown = E.grow_hypergraph(st.hg, h2v_capacity=2 * st.hg.h2v.capacity,
                              h2v_levels=1)
    st2 = dataclasses.replace(
        st, hg=grown,
        times=S._pad_to(st.times, grown.n_edge_slots, 0),
        dirty_epoch=S._pad_to(st.dirty_epoch, grown.n_edge_slots, 0))
    snap2 = query.of_stream(st2)
    assert snap2.shape_key != snap1.shape_key
    ans2 = query.serve(snap2, reqs, max_deg=MAXD, chunk=CHUNK, cache=cache)
    assert cache.misses == 2 * miss1          # stale entries did not serve
    for a, b in zip(ans1, ans2):
        assert (a == b).all()                 # growth preserved the answers
    # same snapshot again: now it caches
    hits0 = cache.hits
    ans3 = query.serve(snap2, reqs, max_deg=MAXD, chunk=CHUNK, cache=cache)
    assert cache.hits > hits0
    for a, b in zip(ans2, ans3):
        assert (a == b).all()
