"""Benchmark runner — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Machine-readable figures
additionally dump JSON next to the CSV — ``BENCH_kernels.json`` (fig19,
the probe hot path) and ``BENCH_query.json`` (fig20, the query service) —
so their perf trajectories are tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--only fig19]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on figure fn name")
    args = ap.parse_args()

    from benchmarks import figures

    print("name,us_per_call,derived")
    all_rows: list[str] = []
    for fn in figures.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # keep the harness going; a figure bug is visible
            rows = [f"{fn.__name__}/ERROR,0,{type(e).__name__}:{e}"]
        for r in rows:
            print(r, flush=True)
        all_rows += rows
        print(f"# {fn.__name__} done in {time.time() - t0:.1f}s", file=sys.stderr)
    for r in figures.table4_summary(all_rows):
        print(r)
    for path, figure, points in (
        ("BENCH_kernels.json", "fig19_fused_kernel", figures.KERNEL_BENCH),
        ("BENCH_query.json", "fig20_query_throughput", figures.QUERY_BENCH),
        ("BENCH_elastic.json", "fig21_elastic_growth", figures.ELASTIC_BENCH),
    ):
        if points:
            with open(path, "w") as f:
                json.dump({"figure": figure, "unit": "us_per_call",
                           "points": points}, f, indent=2)
            print(f"# wrote {path} ({len(points)} points)", file=sys.stderr)


if __name__ == "__main__":
    main()
