"""Shared benchmark harness: scaled dataset profiles + timing utils.

All figures run on the CPU host at scaled-down sizes (Table III datasets are
millions of edges; we keep the *shape statistics* via hypergraph.generators
profiles and scale counts so each figure finishes in seconds).  Numbers to
read: the *relative* contrasts — incremental vs recount, scaling slopes,
cardinality effects — which is what the paper's figures demonstrate.

Output protocol (benchmarks/run.py): ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hypergraph as H
from repro.core.store import EMPTY
from repro.hypergraph import generators as GEN

MAXD = 32          # line-graph degree bound
MAXR = 1023        # affected-region bound
CHUNK = 2048


def build(profile: str, n_edges: int, seed: int = 0, max_card: int = 8,
          card_cap: int = 6):
    n_vert = max(n_edges, 16)  # edge/vertex ratio keeps degrees bounded
    edges = GEN.random_hypergraph(n_edges, n_vert, profile=profile,
                                  max_card=card_cap, seed=seed, skew=0.3)
    hg = H.from_lists(edges, num_vertices=n_vert, max_edges=4 * n_edges,
                      max_card=max_card, slack=4.0)
    return hg, n_vert


def make_batch(hg, n_changes: int, delete_frac: float, n_vert: int,
               max_card: int = 8, card_cap: int = 6, seed: int = 1,
               profile: str = "coauth"):
    present = np.asarray(hg.h2v.mgr.present)
    live = np.asarray(hg.h2v.mgr.hid)[present == 1]
    dels, ins = GEN.churn_batch(live, n_changes, delete_frac, n_vert,
                                max_card, profile=profile, seed=seed,
                                card_cap=card_cap)
    nl, nc = GEN.pack_lists(ins, max_card)
    return (jnp.asarray(dels), jnp.ones(len(dels), bool),
            jnp.asarray(nl), jnp.asarray(nc), jnp.ones(len(ins), bool))


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time in µs; blocks on jax arrays."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6), r


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
