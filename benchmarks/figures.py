"""One benchmark per paper figure/table (ESCHER §V).  Each returns CSV rows
``name,us_per_call,derived``; the derived column carries the figure's
headline quantity (speedup, ratio, count)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CHUNK, MAXD, MAXR, build, make_batch, row, timeit
from repro.core import baselines as BL
from repro.core import hypergraph as H
from repro.core import update as U
from repro.core.store import EMPTY
from repro.hypergraph import generators as GEN

PROFILES = ["coauth", "tags", "threads"]
N_EDGES = 3000


def _update_fn(hg, batch):
    d, dm, nl, nc, im = batch
    counts = jnp.zeros(26, jnp.int32)
    return U.update_triad_counts(hg, counts, d, dm, nl, nc, im,
                                 max_deg=MAXD, max_region=MAXR, chunk=CHUNK)


# ------------------------------------------------------------------ Fig 6a
def fig6a_batch_size():
    out = []
    for prof in PROFILES:
        hg, nv = build(prof, N_EDGES)
        for nch in (100, 200, 400):
            batch = make_batch(hg, nch, 0.5, nv, profile=prof)
            us, _ = timeit(_update_fn, hg, batch)
            out.append(row(f"fig6a/{prof}/changes={nch}", us, "triad-update"))
    return out


# ------------------------------------------------------------------ Fig 6b
def fig6b_scale():
    out = []
    for n in (1500, 3000, 6000):
        hg, nv = build("coauth", n)
        batch = make_batch(hg, 200, 0.5, nv)
        us, _ = timeit(_update_fn, hg, batch)
        out.append(row(f"fig6b/edges={n}", us, "fixed-200-changes"))
    return out


# ------------------------------------------------------------------ Fig 6c
def fig6c_cardinality():
    out = []
    for cap, mc in ((6, 8), (12, 16), (24, 32)):
        hg, nv = build("coauth", N_EDGES, max_card=mc, card_cap=cap)
        batch = make_batch(hg, 200, 0.5, nv, max_card=mc, card_cap=cap)
        us, _ = timeit(_update_fn, hg, batch)
        out.append(row(f"fig6c/card<={cap}", us, "overflow-chaining"))
    return out


# ------------------------------------------------------------------ Fig 6d
def fig6d_vertex_mods():
    out = []
    for prof in PROFILES:
        hg, nv = build(prof, N_EDGES)
        rng = np.random.default_rng(2)
        present = np.asarray(hg.h2v.mgr.present)
        live = np.asarray(hg.h2v.mgr.hid)[present == 1]
        for nch in (100, 200, 400):
            hids = jnp.asarray(rng.choice(live, nch).astype(np.int32))
            vids = jnp.asarray(rng.integers(0, nv, nch).astype(np.int32))
            ins = jnp.asarray(rng.random(nch) < 0.5)
            us, _ = timeit(H.apply_vertex_updates, hg, hids, vids, ins,
                           jnp.ones(nch, bool))
            out.append(row(f"fig6d/{prof}/mods={nch}", us, "incident-vertex"))
    return out


# --------------------------------------------------------------- Fig 7/8/9
def fig7_9_mochy():
    """ESCHER dynamic update vs MoCHy recount (host CPU single-stream) and
    batch-size / delete-ratio sweeps."""
    out = []
    for prof in PROFILES:
        hg, nv = build(prof, N_EDGES)
        # shared-memory MoCHy stand-in: numpy/python recount on the host
        edges_py = list(H.to_python(hg).values())
        t0 = time.perf_counter()
        BL.mochy_cpu(edges_py)
        t_cpu = (time.perf_counter() - t0) * 1e6
        for nch in (100, 400):
            batch = make_batch(hg, nch, 0.5, nv, profile=prof)
            us, _ = timeit(_update_fn, hg, batch)
            out.append(row(f"fig7_9/{prof}/changes={nch}", us,
                           f"speedup_vs_cpu={t_cpu / us:.1f}x"))
    # fig8: deletion-percentage sweep
    hg, nv = build("coauth", N_EDGES)
    for frac in (0.2, 0.4, 0.6, 0.8):
        batch = make_batch(hg, 200, frac, nv)
        us, _ = timeit(_update_fn, hg, batch)
        out.append(row(f"fig8/del={int(frac * 100)}%", us, "triad-update"))
    return out


# ------------------------------------------------------------------ Fig 10
def fig10_mochy_gpu():
    """vs MoCHy device recount (same backend, no incremental machinery)."""
    out = []
    for prof in PROFILES:
        hg, nv = build(prof, N_EDGES)
        us_static, _ = timeit(BL.mochy_static, hg, max_deg=MAXD,
                              max_region=4 * N_EDGES - 1, chunk=CHUNK)
        batch = make_batch(hg, 200, 0.5, nv, profile=prof)
        us_upd, _ = timeit(_update_fn, hg, batch)
        out.append(row(f"fig10/{prof}", us_upd,
                       f"speedup_vs_device_recount={us_static / us_upd:.1f}x"))
    return out


# ------------------------------------------------------------------ Fig 11
def fig11_stathyper():
    out = []
    for prof in ("coauth", "tags"):
        hg, nv = build(prof, 1200)
        v_total = nv
        us_static, _ = timeit(BL.stathyper_static, hg, v_total, max_nb=64,
                              max_region=v_total, chunk=256)
        batch = make_batch(hg, 60, 0.5, nv, profile=prof)

        def upd(hg, batch):
            d, dm, nl, nc, im = batch
            return U.update_vertex_triad_counts(
                hg, jnp.zeros(3, jnp.int32), v_total, d, dm, nl, nc, im,
                max_nb=64, max_region=MAXR, chunk=256)

        us_upd, res = timeit(upd, hg, batch)
        out.append(row(f"fig11/{prof}", us_upd,
                       f"speedup_vs_static={us_static / us_upd:.1f}x"))
    return out


# -------------------------------------------------------------- Fig 12-15
def fig12_15_thyme():
    out = []
    WINDOW = 50
    for prof in PROFILES:
        hg, nv = build(prof, N_EDGES)
        n_slots = hg.n_edge_slots
        rng = np.random.default_rng(5)
        times = jnp.asarray(rng.integers(0, 1000, n_slots).astype(np.int32))
        us_static, _ = timeit(BL.thyme_static, hg, times, WINDOW,
                              max_deg=MAXD, max_region=4 * N_EDGES - 1, chunk=CHUNK)
        for frac in (0.2, 0.5, 0.8):
            batch = make_batch(hg, 200, frac, nv, profile=prof)
            d, dm, nl, nc, im = batch
            ins_t = jnp.asarray(
                rng.integers(1000, 1100, nl.shape[0]).astype(np.int32))

            def upd(hg):
                return U.update_triad_counts(
                    hg, jnp.zeros(128, jnp.int32)[: 102], d, dm, nl, nc, im,
                    max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
                    temporal=True, times=times, ins_times=ins_t, window=WINDOW)

            from repro.core import motifs
            def upd(hg):  # noqa: F811
                return U.update_triad_counts(
                    hg, jnp.zeros(motifs.NUM_TEMPORAL, jnp.int32),
                    d, dm, nl, nc, im,
                    max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
                    temporal=True, times=times, ins_times=ins_t, window=WINDOW)

            us_upd, _ = timeit(upd, hg)
            out.append(row(f"fig12_15/{prof}/del={int(frac * 100)}%", us_upd,
                           f"speedup_vs_static={us_static / us_upd:.1f}x"))
    return out


# ------------------------------------------------------------------ Fig 16
def fig16_hornet():
    """Bytes-moved ratio (Hornet-like pow2 realloc vs ESCHER blocks) as the
    cardinality STD of changed edges grows — the paper's crossover."""
    out = []
    rng = np.random.default_rng(7)
    for std in (1, 4, 16, 64):
        p2 = BL.Pow2Store()
        em = BL.EscherHostModel()
        for key in range(2000):
            card = max(2, int(rng.normal(32, std)))
            vals = rng.integers(0, 10_000, card).astype(np.int32)
            p2.insert_list(key, vals)
            em.insert_list(key, vals)
        for _ in range(4000):  # churn: grow random lists
            key = int(rng.integers(0, 2000))
            p2.append(key, 1)
            em.append(key, 1)
        ratio = p2.bytes_moved / max(em.bytes_moved, 1)
        out.append(row(f"fig16/std={std}", 0.0,
                       f"bytes_ratio_hornet_over_escher={ratio:.2f}"))
    return out


# ------------------------------------------------------------------ Fig 17
def fig17_streaming():
    """Streaming evolution engine (core/stream.py): end-to-end events/sec of
    the scan driver vs batch size, against recount-per-batch — the cost an
    event-log consumer without incremental machinery would pay.  The paper's
    regime: a large standing hypergraph, a small churn stream on top."""
    from repro.core import stream as S

    out = []
    N_BASE, N_EV = 1200, 96
    hg0, nv = build("coauth", N_BASE)
    events = GEN.event_stream(N_EV, nv, profile="coauth", insert_frac=0.6,
                              seed=0, max_card=6, max_dt=2)
    counts0 = BL.mochy_static(hg0, max_deg=MAXD, max_region=4 * N_BASE - 1,
                              chunk=CHUNK)

    def run(batch, steps):
        log = S.log_from_events(events, max_card=8)
        st = S.make_stream(hg0, log, counts0)
        return S.run_stream(st, n_steps=steps, batch=batch, mode="edge",
                            max_deg=MAXD, max_region=MAXR, chunk=CHUNK)

    # recount-per-batch baseline: one full static count of the standing
    # graph per scheduler step (the stream-less alternative)
    us_recount, _ = timeit(BL.mochy_static, hg0, max_deg=MAXD,
                           max_region=4 * N_BASE - 1, chunk=CHUNK)

    for batch in (8, 24, 48):
        steps = S.plan_steps(events, batch)
        us, st = timeit(run, batch, steps)
        evps = N_EV / (us / 1e6)
        speedup = steps * us_recount / us
        out.append(row(f"fig17/batch={batch}", us,
                       f"events_per_sec={evps:.0f};"
                       f"speedup_vs_recount_per_batch={speedup:.1f}x"))
    return out


# ------------------------------------------------------------------ Fig 18
def fig18_sharded_scaling():
    """Sharded triad engine (distributed/triads.py, DESIGN.md §3.2):
    static-count µs/call and streaming events/sec vs device count.  Sweeps
    the device counts available on this host — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get the full
    1/2/4/8 sweep (CI does); on one device only the devices=1 rows emit."""
    from repro.core import stream as S
    from repro.core import triads as T
    from repro.distributed import triads as DT

    out = []
    ndev = len(jax.devices())
    sweep = [d for d in (1, 2, 4, 8) if d <= ndev]
    if sweep[-1] != ndev:
        sweep.append(ndev)      # always measure the full mesh

    # static full-region count: µs/count vs device count
    N = 1500
    hg, nv = build("coauth", N)
    reg, m = T.all_live_region(hg, 4 * N - 1)
    base_us = None
    for d in sweep:
        mesh = DT.count_mesh(d)
        us, res = timeit(DT.count_triads_sharded, hg, reg, m, mesh=mesh,
                         max_deg=MAXD, chunk=CHUNK)
        n_triads = max(int(res.sum()), 1)
        base_us = base_us or us
        out.append(row(f"fig18/static/devices={d}", us,
                       f"us_per_ktriads={1e3 * us / n_triads:.2f};"
                       f"scaling_vs_1dev={base_us / us:.2f}x"))

    # streaming maintenance: events/sec vs device count (fig17's regime —
    # a standing hypergraph with a small churn stream on top)
    N_BASE, N_EV, BATCH = 1200, 64, 16
    hg0, nv = build("coauth", N_BASE)
    events = GEN.event_stream(N_EV, nv, profile="coauth", insert_frac=0.6,
                              seed=0, max_card=6, max_dt=2)
    counts0 = BL.mochy_static(hg0, max_deg=MAXD, max_region=4 * N_BASE - 1,
                              chunk=CHUNK)
    steps = S.plan_steps(events, BATCH)

    def run(mesh):
        log = S.log_from_events(events, max_card=8)
        st = S.make_stream(hg0, log, counts0)
        return S.run_stream(st, n_steps=steps, batch=BATCH, mode="edge",
                            max_deg=MAXD, max_region=MAXR, chunk=CHUNK,
                            mesh=mesh)

    base_us = None
    for d in sweep:
        us, st = timeit(run, DT.count_mesh(d))
        base_us = base_us or us
        out.append(row(f"fig18/stream/devices={d}", us,
                       f"events_per_sec={N_EV / (us / 1e6):.0f};"
                       f"scaling_vs_1dev={base_us / us:.2f}x"))
    return out


# ------------------------------------------------------------------ Fig 19
KERNEL_BENCH: list[dict] = []   # machine-readable rows; run.py dumps them
                                # to BENCH_kernels.json next to the CSV


def fig19_fused_kernel():
    """Fused probe kernel (kernels/ops.fused_triple_stats) vs the unfused
    sequence it replaced — four dispatches (pair + 2× stack + triple; on
    the pallas backend the triple formerly launched membership separately,
    making it five kernel launches) — and the packed-bitset backend, at
    several (n, c, k) points.

    The unfused sequence is timed as it actually executed: one dispatch per
    kernel, each re-streaming the A/B/Cs rows — that is the 4–5× HBM-traffic
    tax the fusion removes.  On this CPU host the xla backend stands in for
    the device kernels; the *ratios* are the figure."""
    import functools

    from repro.kernels import ops as kops

    rng = np.random.default_rng(19)

    def mksets(n, c, univ):
        out = np.full((n, c), EMPTY, np.int32)
        for i in range(n):
            m = int(rng.integers(min(c, univ) // 2, min(c, univ) + 1))
            out[i, :m] = np.sort(rng.choice(univ, size=m, replace=False))
        return jnp.asarray(out)

    # one jit per launch, exactly like the five separate kernel dispatches
    # of the pre-fusion chunk_counter inner loop
    j_pair = jax.jit(functools.partial(kops.pair_intersect_count, backend="xla"))
    j_stack = jax.jit(functools.partial(kops.stack_pair_intersect_count, backend="xla"))
    j_triple = jax.jit(functools.partial(kops.triple_intersect_count, backend="xla"))

    def unfused(a, b, cand):
        return (j_pair(a, b), j_stack(a, cand), j_stack(b, cand),
                j_triple(a, b, cand))

    def fused(backend, n_bits):
        return jax.jit(lambda a, b, cand: kops.fused_triple_stats(
            a, b, cand, backend=backend, n_bits=n_bits))

    out = []
    for n, c, k, V in [(1024, 32, 16, 1024), (512, 128, 8, 4096),
                       (256, 256, 8, 8192)]:
        a, b = mksets(n, c, V), mksets(n, c, V)
        cand = jnp.stack([mksets(k, c, V) for _ in range(n)])
        us_unfused, _ = timeit(unfused, a, b, cand)
        us_fused, _ = timeit(fused("xla", V), a, b, cand)
        us_bitset, _ = timeit(fused("bitset", V), a, b, cand)
        auto = kops.resolve_backend(None, c=c, n_bits=V)
        KERNEL_BENCH.append({
            "n": n, "c": c, "k": k, "n_bits": V,
            "us_unfused": round(us_unfused, 1),
            "us_fused_xla": round(us_fused, 1),
            "us_fused_bitset": round(us_bitset, 1),
            "speedup_fused_vs_unfused": round(us_unfused / us_fused, 2),
            "speedup_bitset_vs_unfused": round(us_unfused / us_bitset, 2),
            "auto_backend": auto,
        })
        # "fused=" not "speedup=": table4 aggregates paper-speedup rows only
        out.append(row(f"fig19/n={n}/c={c}/k={k}", us_fused,
                       f"fused_vs_unfused={us_unfused / us_fused:.1f}x;"
                       f"bitset={us_unfused / us_bitset:.1f}x;auto={auto}"))
    return out


# ------------------------------------------------------------------ Fig 20
QUERY_BENCH: list[dict] = []    # machine-readable rows; run.py dumps them
                                # to BENCH_query.json next to the CSV


def fig20_query_throughput():
    """Triad query service (src/repro/query/, DESIGN.md §7): per-edge point
    queries served three ways at several batch sizes —

      * sequential: one ``count_triads_containing`` jit dispatch per query
        (the pre-subsystem alternative: N launches, each re-deriving its
        neighbour rows, each padding its own work-list);
      * batched: ONE ``count_triads_containing_each`` call against the
        epoch-level neighbour index — the N probe work-lists concatenate,
        validity-compact, and share padded chunk launches; the index
        (``triads.neighbor_table``, built once per epoch, cost reported as
        ``us_index_build``) turns work-list derivation into gathers;
      * cold / warm cache: the full ``query.serve`` path with an empty vs
        a pre-filled ``QueryCache`` (steady-state localized-churn traffic
        answers from host lookups).

    The acceptance line is the batched vs sequential ratio at batch ≥ 64;
    warm-cache hits are reported separately."""
    from repro.core import triads as T
    from repro import query

    hg, nv = build("coauth", 1500)
    present = np.asarray(hg.h2v.mgr.present)
    live = np.asarray(hg.h2v.mgr.hid)[present == 1]
    rng = np.random.default_rng(20)
    snap = query.of_graph(hg)
    out = []

    us_index, table = timeit(T.neighbor_table, hg, max_deg=MAXD)

    for B in (16, 64, 128):
        ranks = jnp.asarray(rng.choice(live, B, replace=False).astype(np.int32))
        mask = jnp.ones(B, bool)

        def sequential(ranks):
            one = jnp.ones(1, bool)
            return jnp.stack([
                T.count_triads_containing(hg, ranks[i: i + 1], one,
                                          max_deg=MAXD, chunk=CHUNK)
                for i in range(B)])

        def batched(ranks, mask):
            return T.count_triads_containing_each(
                hg, ranks, mask, max_deg=MAXD, chunk=CHUNK,
                nbrs_table=table)

        us_seq, ref = timeit(sequential, ranks)
        us_bat, got = timeit(batched, ranks, mask)
        assert (np.asarray(got) == np.asarray(ref)).all()

        reqs = [query.triads_containing_edge(int(r)) for r in ranks]
        serve_kw = dict(max_deg=MAXD, chunk=CHUNK, max_region=MAXR)

        def serve_cold(reqs):
            # a fresh cache: pays the index build + the batched lowering,
            # i.e. the first traffic to arrive at a new epoch
            return query.serve(snap, reqs, cache=query.QueryCache(),
                               **serve_kw)

        warm = query.QueryCache()
        query.serve(snap, reqs, cache=warm, **serve_kw)   # prefill

        def serve_warm(reqs):
            return query.serve(snap, reqs, cache=warm, **serve_kw)

        us_cold, _ = timeit(serve_cold, reqs)
        us_warm, _ = timeit(serve_warm, reqs)

        QUERY_BENCH.append({
            "batch": B,
            "us_sequential": round(us_seq, 1),
            "us_batched": round(us_bat, 1),
            "us_index_build": round(us_index, 1),
            "us_serve_cold": round(us_cold, 1),
            "us_serve_warm": round(us_warm, 1),
            "speedup_batched_vs_sequential": round(us_seq / us_bat, 2),
            "speedup_warm_vs_cold": round(us_cold / us_warm, 2),
            "warm_us_per_query": round(us_warm / B, 2),
        })
        # "batched=" not "speedup=": table4 aggregates paper-speedup rows only
        out.append(row(f"fig20/batch={B}", us_bat,
                       f"batched_vs_sequential={us_seq / us_bat:.1f}x;"
                       f"warm_cache_vs_cold={us_cold / us_warm:.1f}x"))
    return out


# ------------------------------------------------------------------ Fig 21
ELASTIC_BENCH: list[dict] = []  # machine-readable rows; run.py dumps them
                                # to BENCH_elastic.json next to the CSV


def fig21_elastic_growth():
    """Elastic store (core/elastic.py, DESIGN.md §8): an unbounded stream
    ingested from a *minimally sized* store under
    ``run_stream(auto_grow=True)`` vs a pre-sized oracle run — the same
    events into a store already sized at the elastic run's final capacity.

    The headline quantities: the growth factor the elastic run survives
    (acceptance floor: >= 8x on the h2v store), bit-identical final
    histograms in all three triad modes (edge / temporal / vertex), and
    the throughput tax of elasticity (events/sec ratio vs the oracle,
    measured after warmup: recompiles are amortised away, but the
    rolled-back segment re-runs and per-segment host syncs are charged —
    that IS the price of growing ~8x mid-stream at this toy scale)."""
    from repro.core import motifs
    from repro.core import stream as S

    N_EV, BATCH, SEG = 60, 8, 4
    NV, MAXCE = 24, 8
    kw = dict(max_deg=16, max_nb=16, max_region=127, chunk=256)
    events = GEN.event_stream(N_EV, NV, profile="coauth", insert_frac=0.85,
                              seed=21, max_card=6, max_dt=2)
    steps = S.plan_steps(events, BATCH)
    n_out = {"edge": motifs.NUM_CLASSES, "temporal": motifs.NUM_TEMPORAL,
             "vertex": 3}

    def tiny_hg():
        return H.from_lists([], num_vertices=NV, max_edges=8,
                            max_card=MAXCE, max_vdeg=24, granule=8,
                            slack=1.0, min_capacity=64)

    def run(hg0, mode, auto, grow_log=None):
        log = S.log_from_events(events, max_card=MAXCE)
        st = S.make_stream(hg0, log,
                           jnp.zeros(n_out[mode], jnp.int32))
        return S.run_stream(
            st, n_steps=steps, batch=BATCH, mode=mode,
            window=40 if mode == "temporal" else None,
            v_total=NV if mode == "vertex" else 0,
            auto_grow=auto, segment=SEG, grow_log=grow_log, **kw)

    out = []
    for mode in ("edge", "temporal", "vertex"):
        grow_log: list[dict] = []
        run(tiny_hg(), mode, True, grow_log)          # discover the repairs
        us_elastic, st = timeit(run, tiny_hg(), mode, True)
        assert int(st.error) == 0, S.decode_errors(st)
        tiny = tiny_hg()
        growth = st.hg.h2v.capacity / tiny.h2v.capacity

        presized = H.from_lists(
            [], num_vertices=NV, max_edges=st.hg.n_edge_slots,
            max_card=MAXCE, max_vdeg=24, granule=8,
            min_capacity=max(st.hg.h2v.capacity, st.hg.v2h.capacity))
        us_oracle, ref = timeit(run, presized, mode, False)
        assert int(ref.error) == 0
        identical = bool((np.asarray(st.counts)
                          == np.asarray(ref.counts)).all())

        ELASTIC_BENCH.append({
            "mode": mode,
            "initial_capacity": tiny.h2v.capacity,
            "final_capacity": st.hg.h2v.capacity,
            "growth_factor": round(growth, 1),
            "final_tree_height": st.hg.h2v.mgr.height,
            "n_repairs": len(grow_log),
            "histograms_identical": identical,
            "events_per_sec_elastic": round(N_EV / (us_elastic / 1e6)),
            "events_per_sec_presized": round(N_EV / (us_oracle / 1e6)),
            "elastic_overhead": round(us_elastic / us_oracle, 2),
        })
        # "identical=" not "speedup=": table4 aggregates speedup rows only
        out.append(row(
            f"fig21/{mode}", us_elastic,
            f"growth={growth:.0f}x;repairs={len(grow_log)};"
            f"identical={identical};overhead_vs_presized="
            f"{us_elastic / us_oracle:.2f}x"))
    return out


# ------------------------------------------------------------------ Table IV
def table4_summary(rows: list[str]) -> list[str]:
    import re
    speeds = [float(m.group(1)) for r in rows
              for m in [re.search(r"speedup[^=]*=(\d+\.?\d*)x", r)] if m]
    if not speeds:
        return []
    return [row("table4/speedup_avg", 0.0, f"{np.mean(speeds):.1f}x"),
            row("table4/speedup_max", 0.0, f"{np.max(speeds):.1f}x")]


ALL = [fig6a_batch_size, fig6b_scale, fig6c_cardinality, fig6d_vertex_mods,
       fig7_9_mochy, fig10_mochy_gpu, fig11_stathyper, fig12_15_thyme,
       fig16_hornet, fig17_streaming, fig18_sharded_scaling,
       fig19_fused_kernel, fig20_query_throughput, fig21_elastic_growth]
